"""In-scan windowed BA + blocked Schur marginalization (core.backend.ba
+ kernels.marg_schur): numerical equivalence with the host-stage
reference, keyframe-window semantics, and the trigger parity between the
fused/chunked paths and the host rule they replace."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.backend import ba, mapping
from repro.core.environment import Environment
from repro.core.localizer import Localizer
from repro.kernels import marg_schur, registry


def _problem(m=32, seed=0):
    return registry._marg_inputs(m)


@pytest.mark.parametrize("use_pallas", [False, True])
def test_marginalize_schur_matches_reference(use_pallas):
    """The blocked Schur formulation == mapping.marginalize (the seed's
    dense elimination) on both kernel paths."""
    Hpp, Hpl, Hll, bp, bl = _problem()
    h_ref, b_ref = mapping.marginalize(Hpp, Hpl, Hll, bp, bl)
    h, b = ba.marginalize_schur(Hpp, Hpl, Hll, bp, bl,
                                jnp.bool_(use_pallas))
    np.testing.assert_allclose(np.asarray(h), np.asarray(h_ref), atol=1e-4)
    np.testing.assert_allclose(np.asarray(b), np.asarray(b_ref), atol=1e-4)


def test_registry_marg_schur_paths_agree():
    """Both registry impls of the widened (normal-eq assembly + Schur)
    reduction produce the same (Y, y) — the fused Pallas kernel is a
    drop-in for the XLA path."""
    spec = registry.REGISTRY["marg_schur"]
    r, jx, jl = registry._marg_schur_inputs(32)
    yx, vx = spec.xla(r, jx, jl)
    yp, vp = spec.pallas(r, jx, jl)
    np.testing.assert_allclose(np.asarray(yx), np.asarray(yp), atol=1e-4)
    np.testing.assert_allclose(np.asarray(vx), np.asarray(vp), atol=1e-4)


def test_marg_schur_blocking_invariant():
    """Landmark-tile size must not change the widened reduction."""
    r, jx, jl = registry._marg_schur_inputs(48)
    y1, v1 = marg_schur.accumulate_normal(r, jx, jl, mb=4)
    y2, v2 = marg_schur.accumulate_normal(r, jx, jl, mb=48)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=1e-4)
    np.testing.assert_allclose(np.asarray(v1), np.asarray(v2), atol=1e-4)


def test_marg_schur_normal_matches_legacy_assembly():
    """The fused JᵀJ-assembly kernel == build_normal_eqs + the legacy
    blocked reduction, on both paths (the materialized Hpl/Hll/bl the
    fusion removed)."""
    r, jx, jl = registry._marg_schur_inputs(48)
    k, m = jx.shape[0], jx.shape[1]
    Hpp, Hpl, Hll, bp, bl = mapping.build_normal_eqs(r, jx, jl)
    g = Hpl.transpose(1, 0, 2, 3).reshape(m, 6 * k, 3)
    a = Hll + 1e-4 * jnp.eye(3)[None]
    y_ref, v_ref = marg_schur.accumulate_ref(g, a, bl)
    y0, v0 = marg_schur.accumulate_normal_ref(r, jx, jl)
    np.testing.assert_array_equal(np.asarray(y0), np.asarray(y_ref))
    np.testing.assert_array_equal(np.asarray(v0), np.asarray(v_ref))
    y1, v1 = marg_schur.accumulate_normal(r, jx, jl)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y_ref), atol=1e-4)
    np.testing.assert_allclose(np.asarray(v1), np.asarray(v_ref), atol=1e-4)


def test_push_keyframe_window_semantics():
    """The ring fills front-to-back, then shifts left: slot 0 is always
    the oldest keyframe (the gauge anchor / marginalization target) and
    n_kf saturates at the window size."""
    kw = 4
    st = ba.init_ba_state(kw)
    for i in range(6):
        R = jnp.eye(3) * (i + 1.0)
        p = jnp.full((3,), float(i))
        st = ba.push_keyframe(st, R, p)
        if i < kw:
            assert int(st.n_kf) == i + 1
            assert float(st.kf_p[i][0]) == float(i)
    assert int(st.n_kf) == kw
    assert bool(st.kf_valid.all())
    # after 6 pushes of poses 0..5 into a window of 4: oldest is pose 2
    np.testing.assert_allclose(np.asarray(st.kf_p)[:, 0], [2, 3, 4, 5])


def test_backproject_matches_host_stereo_points():
    """Traced back-projection == the host stage's stereo_points_world."""
    from repro.core.localizer import np_quat_to_rot, stereo_points_world

    class Cam:
        fx = fy = 100.0
        cx = 40.0
        cy = 30.0
        baseline = 0.12

    rs = np.random.RandomState(0)
    n = 64
    yx = rs.randint(0, 60, (n, 2)).astype(np.int32)
    disp = rs.rand(n).astype(np.float32) * 20
    svalid = rs.rand(n) > 0.3
    R = np_quat_to_rot(np.array([0.9, 0.1, 0.2, 0.38]))
    p = np.array([1.0, -2.0, 3.0], np.float32)
    kf = {"yx": yx.astype(np.float32), "disparity": disp, "svalid": svalid,
          "pose_R": R, "pose_p": p}
    pts_ref, valid_ref = stereo_points_world(kf, Cam)
    pts, valid = ba.backproject_stereo(
        jnp.asarray(yx), jnp.asarray(disp), jnp.asarray(svalid),
        jnp.asarray(R), jnp.asarray(p), fx=Cam.fx, fy=Cam.fy, cx=Cam.cx,
        cy=Cam.cy, baseline=Cam.baseline)
    np.testing.assert_array_equal(np.asarray(valid), valid_ref)
    np.testing.assert_allclose(np.asarray(pts)[valid_ref],
                               pts_ref[valid_ref], rtol=1e-4)


def _drive_slam(loc, seq, n):
    env = Environment(False, False)
    v0 = (seq.poses[1][:3, 3] - seq.poses[0][:3, 3]) / seq.dt
    st = loc.init_state(p0=seq.poses[0][:3, 3], v0=v0)
    ipf = seq.imu_per_frame
    for i in range(n):
        a = seq.imu_accel[max(i - 1, 0) * ipf:max(i, 1) * ipf]
        g = seq.imu_gyro[max(i - 1, 0) * ipf:max(i, 1) * ipf]
        st = loc.step(st, seq.images_left[i], seq.images_right[i], a, g,
                      None, env, seq.dt / ipf)
    return st


def test_inscan_ba_matches_host_trigger(synthetic_sequence, small_cfg):
    """The in-scan BA fires on the host path's exact rule: >= 3
    keyframes pushed and an even frame index."""
    n = 8
    loc = Localizer(small_cfg, synthetic_sequence.cam, window=8)
    st = _drive_slam(loc, synthetic_sequence, n)
    expected = sum(1 for i in range(n)
                   if i + 1 >= small_cfg.backend.ba_min_keyframes
                   and i % small_cfg.backend.ba_every == 0)
    assert loc.ba_runs == expected
    # the BA really ran: the marginalization prior is a live, symmetric,
    # finite matrix and the window saturated
    h = np.asarray(st.ba.H_prior)
    assert np.isfinite(h).all() and np.abs(h).max() > 0
    np.testing.assert_allclose(h, h.T, atol=1e-5)
    assert int(st.ba.n_kf) == min(n, small_cfg.backend.ba_window)
    assert np.isfinite(float(st.ba.last_cost))


def test_offload_plan_gates_inscan_ba(synthetic_sequence, small_cfg):
    """plan.marginalization=False skips the in-scan BA round entirely —
    the same accuracy-for-latency skip the host stage implemented (and
    the kalman gate's pattern): a flag, not a retrace, and the SLAM map
    bookkeeping still runs."""
    from repro.core import scheduler as sched

    class NeverOffload(sched.LatencyModels):
        def should_offload(self, name, size, transfer_bytes=0,
                           overhead_s=None, transfer_bw=None):
            return False

    loc = Localizer(small_cfg, synthetic_sequence.cam, window=8,
                    scheduler=NeverOffload())
    st = _drive_slam(loc, synthetic_sequence, 6)
    assert loc.ba_runs == 0
    assert loc.fused_trace_count() == 1
    # keyframes were still pushed (the window carries state even when
    # the BA round is gated off) and the map still grew
    assert int(st.ba.n_kf) == min(6, small_cfg.backend.ba_window)
    assert float(np.abs(np.asarray(st.ba.H_prior)).max()) == 0.0
    assert len(loc._slam_keyframes) == 6
