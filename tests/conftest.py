import os
import sys
from pathlib import Path

# tests must see the real device count (1 CPU device) — the 512-device
# flag is only ever set inside repro.launch.dryrun subprocesses.
sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

import numpy as np
import pytest


@pytest.fixture(scope="session")
def rng():
    import jax
    return jax.random.PRNGKey(0)


@pytest.fixture(scope="session")
def synthetic_sequence():
    """One shared small synthetic stereo/IMU/GPS sequence."""
    from repro.data import frames
    return frames.generate(n_frames=14, H=120, W=160, n_landmarks=240,
                           gps_available=True, accel_sigma=0.5,
                           gyro_sigma=0.02, seed=0)


@pytest.fixture(scope="session")
def small_cfg():
    """The shared 120x160/128-feature localization config (matches
    synthetic_sequence's frame size). The in-scan BA window/budget is
    shrunk to keep per-test compile time down — BA numerics have their
    own full-size tests in test_ba.py."""
    import dataclasses
    from repro.configs.eudoxus import EDX_DRONE
    fe = dataclasses.replace(EDX_DRONE.frontend, height=120, width=160,
                             max_features=128)
    be = dataclasses.replace(EDX_DRONE.backend, ba_window=5,
                             ba_landmarks=16, lm_iters=3)
    return dataclasses.replace(EDX_DRONE, frontend=fe, backend=be)


@pytest.fixture()
def no_kalman_offload_scheduler():
    """LatencyModels forcing the kalman_gain kernel onto the host path
    (offload_kalman=False) while every other kernel offloads — shared by
    the host-Kalman-fallback tests."""
    import repro.core.scheduler as sched

    class NoKalmanOffload(sched.LatencyModels):
        def should_offload(self, name, size, transfer_bytes=0,
                           overhead_s=None, transfer_bw=None):
            return name != "kalman_gain"

    return NoKalmanOffload
