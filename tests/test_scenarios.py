"""Scenario-primitive registry: the compiled step must be BITWISE equal
to the pre-refactor monolith for the legacy VIO/SLAM/Registration modes
on every execution path (per-frame, chunked K in {1,4,8}, fleet,
1-device mesh, mixed-scenario fleets), one compiled program must serve
every registered scenario (trace counts), the two new scenarios
(DRONE_VIO, VIO_DEGRADED) must run end-to-end, unknown mode ids must
raise host-side and pass through in-scan, and registering a new
scenario must never touch ``core.step``."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import scenarios as scen
from repro.core import scheduler as sched
from repro.core import step as step_mod
from repro.core.environment import (MODE_DRONE_VIO, MODE_REGISTRATION,
                                    MODE_SLAM, MODE_VIO, MODE_VIO_DEGRADED,
                                    Environment, select_mode_id)
from repro.core.step import (FrameInputs, flags_from_plan,
                             init_localizer_state, localize_step)
from repro.data import frames

import reference_monolith as mono

WINDOW = 4


@pytest.fixture(scope="module")
def tiny_cfg():
    """Embedded-scale config: small enough that the module's many
    jit compiles stay cheap, BA budgets shrunk likewise."""
    from repro.configs.eudoxus import EDX_DRONE
    fe = dataclasses.replace(EDX_DRONE.frontend, height=48, width=64,
                             max_features=48)
    be = dataclasses.replace(EDX_DRONE.backend, ba_window=4,
                             ba_landmarks=16, lm_iters=2)
    return dataclasses.replace(EDX_DRONE, frontend=fe, backend=be)


@pytest.fixture(scope="module")
def tiny_seq():
    return frames.generate(n_frames=12, H=48, W=64, n_landmarks=200,
                           accel_sigma=0.5, gyro_sigma=0.02, seed=0)


@pytest.fixture(scope="module")
def bind(tiny_cfg, tiny_seq):
    """Shared static bindings (incl. one vocab both paths bake in)."""
    from repro.core.backend import tracking
    cam = tiny_seq.cam
    return dict(cfg=tiny_cfg.frontend, be_cfg=tiny_cfg.backend,
                fx=cam.fx, fy=cam.fy, cx=cam.cx, cy=cam.cy,
                baseline=cam.baseline,
                vocab=jnp.asarray(
                    tracking.make_vocab(tiny_cfg.backend.bow_vocab_size)))


def _flags(modes):
    return flags_from_plan(sched.OffloadPlan(marg_schur=False), modes=modes)


def _frame_args(seq, i):
    ipf = seq.imu_per_frame
    a = seq.imu_accel[max(i - 1, 0) * ipf:max(i, 1) * ipf]
    g = seq.imu_gyro[max(i - 1, 0) * ipf:max(i, 1) * ipf]
    return (jnp.asarray(seq.images_left[i]), jnp.asarray(seq.images_right[i]),
            jnp.asarray(a), jnp.asarray(g))


def _assert_trees_equal(a, b):
    for la, lb in zip(jax.tree_util.tree_leaves(a),
                      jax.tree_util.tree_leaves(b)):
        np.testing.assert_array_equal(np.asarray(la), np.asarray(lb))


def _chunk_inputs(seq, idxs, mode_ids, K):
    """Padded FrameInputs chunk over ``idxs`` with per-frame modes."""
    ipf = seq.imu_per_frame
    n = len(idxs)
    pad = K - n

    def stk(per):
        arr = np.stack([np.asarray(per(i), np.float32) for i in idxs])
        if pad:
            arr = np.concatenate(
                [arr, np.zeros((pad,) + arr.shape[1:], np.float32)])
        return arr

    return FrameInputs(
        img_l=stk(lambda i: seq.images_left[i]),
        img_r=stk(lambda i: seq.images_right[i]),
        accel=stk(lambda i: seq.imu_accel[max(i - 1, 0) * ipf:
                                          max(i, 1) * ipf]),
        gyro=stk(lambda i: seq.imu_gyro[max(i - 1, 0) * ipf:
                                        max(i, 1) * ipf]),
        gps=stk(lambda i: seq.gps[i]),
        mode=np.concatenate([np.asarray(mode_ids, np.int32)[:n],
                             np.zeros(pad, np.int32)]),
        active=np.concatenate([np.ones(n, bool), np.zeros(pad, bool)]))


# --------------------------------------------------------------------------
# bitwise equivalence with the pre-refactor monolith
# --------------------------------------------------------------------------

@pytest.mark.parametrize("mode", [MODE_VIO, MODE_SLAM, MODE_REGISTRATION])
def test_compiled_matches_monolith_per_frame(tiny_cfg, tiny_seq, bind, mode):
    """Registry-compiled step == frozen monolith, every state leaf and
    every scan output bitwise, for each legacy backend."""
    seq = tiny_seq
    flags = _flags((mode,))
    dt = jnp.float32(seq.dt / seq.imu_per_frame)
    new = jax.jit(lambda st, *a: localize_step(st, *a, **bind))
    old = jax.jit(
        lambda st, *a: mono.localize_step_monolith(st, *a, **bind))
    st_n = init_localizer_state(tiny_cfg, WINDOW, p0=seq.poses[0][:3, 3])
    st_o = init_localizer_state(tiny_cfg, WINDOW, p0=seq.poses[0][:3, 3])
    for i in range(8):
        il, ir, a, g = _frame_args(seq, i)
        gps = jnp.asarray(seq.gps[i])
        m = jnp.int32(mode)
        st_n, out_n = new(st_n, il, ir, a, g, gps, m, flags, dt)
        st_o, out_o = old(st_o, il, ir, a, g, gps, m, flags, dt)
    _assert_trees_equal(st_n, st_o)
    _assert_trees_equal(out_n, out_o)


def test_compiled_matches_monolith_chunked(tiny_cfg, tiny_seq, bind):
    """K in {1,4,8} chunk scans (mixed legacy modes, padded partial
    chunks included) reproduce the monolith scan bitwise."""
    seq = tiny_seq
    mode_ids = [MODE_SLAM] * 4 + [MODE_VIO] * 4 + [MODE_REGISTRATION] * 2
    flags = _flags(mode_ids)
    dt = jnp.float32(seq.dt / seq.imu_per_frame)
    for K in (1, 4, 8):
        new = jax.jit(lambda st, inp: step_mod.localize_chunk(
            st, inp, flags, dt, **bind))
        old = jax.jit(lambda st, inp: mono.localize_chunk_monolith(
            st, inp, flags, dt, **bind))
        st_n = init_localizer_state(tiny_cfg, WINDOW,
                                    p0=seq.poses[0][:3, 3])
        st_o = init_localizer_state(tiny_cfg, WINDOW,
                                    p0=seq.poses[0][:3, 3])
        for s in range(0, 10, K):
            idxs = list(range(s, min(s + K, 10)))
            inputs = _chunk_inputs(seq, idxs, mode_ids[s:s + K], K)
            st_n, out_n = new(st_n, jax.device_put(inputs))
            st_o, out_o = old(st_o, jax.device_put(inputs))
        _assert_trees_equal(st_n, st_o)
        _assert_trees_equal(out_n, out_o)


def _fleet_states(cfg, seq, B):
    sts = [init_localizer_state(cfg, WINDOW, p0=seq.poses[0][:3, 3])
           for _ in range(B)]
    return jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *sts)


def _fleet_inputs(seq, T, K, mode_ids):
    B = len(mode_ids)
    per = [_chunk_inputs(seq, list(range(T)), [m] * T, K)
           for m in mode_ids]
    return jax.tree_util.tree_map(lambda *xs: np.stack(xs, axis=1), *per)


def test_compiled_matches_monolith_fleet_and_mesh(tiny_cfg, tiny_seq, bind):
    """A mixed-mode fleet chunk (B=3: VIO/SLAM/Registration) reproduces
    the monolith fleet scan bitwise — unsharded AND through a 1-device
    robots mesh (shard_map)."""
    from repro.distributed.fleet_mesh import fleet_mesh, shard_fleet_chunk
    seq = tiny_seq
    mode_ids = np.array([MODE_VIO, MODE_SLAM, MODE_REGISTRATION], np.int32)
    flags = _flags(mode_ids)
    dt = jnp.float32(seq.dt / seq.imu_per_frame)
    T = K = 6
    inputs = _fleet_inputs(seq, T, K, mode_ids)

    new = jax.jit(lambda st, inp: step_mod.fleet_chunk(
        st, inp, flags, dt, **bind))
    old = jax.jit(lambda st, inp: mono.fleet_chunk_monolith(
        st, inp, flags, dt, **bind))
    st_n, out_n = new(_fleet_states(tiny_cfg, seq, 3),
                      jax.device_put(inputs))
    st_o, out_o = old(_fleet_states(tiny_cfg, seq, 3),
                      jax.device_put(inputs))
    _assert_trees_equal(st_n, st_o)
    _assert_trees_equal(out_n, out_o)

    mesh = fleet_mesh(jax.devices()[:1])
    sharded = jax.jit(shard_fleet_chunk(
        lambda st, inp, fl, d: step_mod.fleet_chunk(st, inp, fl, d, **bind),
        mesh))
    st_s, out_s = sharded(_fleet_states(tiny_cfg, seq, 3),
                          jax.device_put(inputs), flags, dt)
    _assert_trees_equal(st_s, st_o)
    _assert_trees_equal(out_s, out_o)


# --------------------------------------------------------------------------
# one compiled program serves every registered scenario
# --------------------------------------------------------------------------

def test_mixed_scenario_fleet_single_trace(tiny_cfg, tiny_seq):
    """All five shipped scenarios in ONE fleet chunk program: a robot
    per scenario, chunked run, exactly one trace, finite estimates —
    and the two new scenarios match their solo single-robot runs."""
    from repro.core.fleet import FleetLocalizer
    seq = tiny_seq
    B, T = 5, 8
    il, ir, ac, gy, gps = frames.tile_fleet_sequence(seq, B, T)
    mode_ids = np.array([MODE_VIO, MODE_SLAM, MODE_REGISTRATION,
                         MODE_DRONE_VIO, MODE_VIO_DEGRADED], np.int32)
    gps = gps.copy()
    gps[:, np.isin(mode_ids, [MODE_SLAM, MODE_REGISTRATION,
                              MODE_DRONE_VIO])] = np.nan
    fleet = FleetLocalizer(tiny_cfg, seq.cam, batch=B, window=WINDOW)
    states = fleet.init_state(
        p0=np.tile(seq.poses[0][:3, 3], (B, 1)))
    states = fleet.run(states, il, ir, ac, gy, gps, mode_ids,
                       seq.dt / seq.imu_per_frame, chunk=4)
    assert fleet.chunk_trace_count() == 1, \
        "mixing scenarios retraced the fleet chunk program"
    pos = fleet.positions(states)
    assert np.all(np.isfinite(pos))

    # each new scenario's row must equal a solo fleet of that scenario
    for mid in (MODE_DRONE_VIO, MODE_VIO_DEGRADED):
        b = int(np.nonzero(mode_ids == mid)[0][0])
        solo = FleetLocalizer(tiny_cfg, seq.cam, batch=1, window=WINDOW)
        s1 = solo.init_state(p0=seq.poses[0][:3, 3][None])
        s1 = solo.run(s1, il[:, b:b + 1], ir[:, b:b + 1], ac[:, b:b + 1],
                      gy[:, b:b + 1], gps[:, b:b + 1],
                      mode_ids[b:b + 1], seq.dt / seq.imu_per_frame,
                      chunk=4)
        # B=1 and B=5 compile separate batched programs; rows agree to
        # float tolerance (the existing fleet-vs-single contract)
        np.testing.assert_allclose(solo.positions(s1)[0], pos[b],
                                   atol=1e-5)


def test_per_frame_scenario_sweep_single_trace(tiny_cfg, tiny_seq):
    """The per-frame fused path crosses all five scenarios without
    retracing (mode is data, not a trace signature)."""
    from repro.core.localizer import Localizer
    seq = tiny_seq
    envs = [Environment(True, False),                      # vio
            Environment(False, False),                     # slam
            Environment(False, True),                      # registration
            Environment(False, False, airborne=True),      # drone_vio
            Environment(True, False, gps_degraded=True),   # vio_degraded
            Environment(True, False)]
    loc = Localizer(tiny_cfg, seq.cam, window=WINDOW)
    st = loc.init_state(p0=seq.poses[0][:3, 3])
    ipf = seq.imu_per_frame
    for i, env in enumerate(envs):
        a = seq.imu_accel[max(i - 1, 0) * ipf:max(i, 1) * ipf]
        g = seq.imu_gyro[max(i - 1, 0) * ipf:max(i, 1) * ipf]
        gps = seq.gps[i] if env.gps_available else None
        st = loc.step(st, seq.images_left[i], seq.images_right[i], a, g,
                      gps, env, seq.dt / ipf)
    assert loc.fused_trace_count() == 1
    assert np.all(np.isfinite(np.asarray(st.filt.p)))


# --------------------------------------------------------------------------
# new-scenario semantics
# --------------------------------------------------------------------------

def test_drone_vio_is_vio_without_gps_fusion(tiny_cfg, tiny_seq, bind):
    """DRONE_VIO's pipeline is the spine alone: with no GPS it matches
    VIO's NaN-outage behavior, with GPS present it must DIFFER (VIO
    fuses, the drone spec declares no gps_fusion primitive)."""
    seq = tiny_seq
    flags = _flags((MODE_VIO, MODE_DRONE_VIO))
    dt = jnp.float32(seq.dt / seq.imu_per_frame)
    step = jax.jit(lambda st, *a: localize_step(st, *a, **bind))

    def drive(mode, gps_on):
        st = init_localizer_state(tiny_cfg, WINDOW, p0=seq.poses[0][:3, 3])
        for i in range(6):
            il, ir, a, g = _frame_args(seq, i)
            gps = (jnp.asarray(seq.gps[i]) if gps_on
                   else jnp.full(3, jnp.nan))
            st, _ = step(st, il, ir, a, g, gps, jnp.int32(mode), flags, dt)
        return st

    # no usable GPS: equivalent filters. NOT bitwise — VIO still runs
    # the zero-weight gps_update, whose apply_correction renormalizes
    # the quaternion (float-level rounding); the drone pipeline omits
    # the primitive entirely.
    st_d, st_v = drive(MODE_DRONE_VIO, False), drive(MODE_VIO, False)
    for ld, lv in zip(jax.tree_util.tree_leaves(st_d),
                      jax.tree_util.tree_leaves(st_v)):
        np.testing.assert_allclose(np.asarray(ld, np.float32),
                                   np.asarray(lv, np.float32),
                                   atol=1e-5)
    # valid GPS: VIO fuses it, the drone pipeline must not
    p_vio = np.asarray(drive(MODE_VIO, True).filt.p)
    p_drone = np.asarray(drive(MODE_DRONE_VIO, True).filt.p)
    assert not np.allclose(p_vio, p_drone)


def test_vio_degraded_downweights_gps(tiny_cfg, tiny_seq, bind):
    """VIO_DEGRADED fuses the same fixes with an inflated sigma: its
    covariance must stay wider than plain VIO's under identical
    inputs."""
    seq = tiny_seq
    flags = _flags((MODE_VIO, MODE_VIO_DEGRADED))
    dt = jnp.float32(seq.dt / seq.imu_per_frame)
    step = jax.jit(lambda st, *a: localize_step(st, *a, **bind))

    def drive(mode):
        st = init_localizer_state(tiny_cfg, WINDOW, p0=seq.poses[0][:3, 3])
        for i in range(6):
            il, ir, a, g = _frame_args(seq, i)
            st, _ = step(st, il, ir, a, g, jnp.asarray(seq.gps[i]),
                         jnp.int32(mode), flags, dt)
        return st

    tr_vio = float(np.trace(np.asarray(drive(MODE_VIO).filt.P)[:6, :6]))
    tr_deg = float(np.trace(
        np.asarray(drive(MODE_VIO_DEGRADED).filt.P)[:6, :6]))
    assert tr_deg > tr_vio, (tr_deg, tr_vio)

    spec = scen.SCENARIOS["vio_degraded"]
    assert spec.pipeline[-1].param_dict()["sigma_gps"] == 0.25


def test_spec_knobs_apply(tiny_cfg):
    """apply_spec folds the drone knobs (smaller clone window, higher
    IMU rate, BA cadence) into a derived config."""
    drone = scen.SCENARIOS["drone_vio"]
    cfg2, window = scen.apply_spec(tiny_cfg, drone)
    assert window == 12 < tiny_cfg.backend.msckf_window
    assert cfg2.backend.imu_rate_hz == 400 > tiny_cfg.backend.imu_rate_hz
    cfg3, w3 = scen.apply_spec(tiny_cfg, scen.SCENARIOS["vio"])
    assert w3 == tiny_cfg.backend.msckf_window
    assert cfg3.backend == tiny_cfg.backend


# --------------------------------------------------------------------------
# unknown mode ids: host-side raise, in-scan pass-through
# --------------------------------------------------------------------------

def test_unknown_mode_id_raises_host_side(tiny_cfg, tiny_seq):
    from repro.core.fleet import FleetLocalizer
    seq = tiny_seq
    B, T = 2, 2
    il, ir, ac, gy, gps = frames.tile_fleet_sequence(seq, B, T)
    fleet = FleetLocalizer(tiny_cfg, seq.cam, batch=B, window=WINDOW)
    states = fleet.init_state()
    bad = np.array([MODE_VIO, 99], np.int32)
    with pytest.raises(ValueError, match="unknown mode id"):
        fleet.run(states, il, ir, ac, gy, gps, bad,
                  seq.dt / seq.imu_per_frame, chunk=2)
    with pytest.raises(ValueError, match="unknown mode id"):
        fleet.step(states, il[0], ir[0], ac[0], gy[0], gps[0],
                   np.array([-1, MODE_VIO], np.int32),
                   seq.dt / seq.imu_per_frame)


def test_out_of_range_mode_passes_through_in_scan(tiny_cfg, tiny_seq, bind):
    """In-scan, an out-of-range id takes the pass-through branch (spine
    only — exactly what Registration's in-scan half does), NOT the old
    clamp-to-Registration... which happened to be the same backend, but
    now also NOT SLAM's heavy block or VIO's GPS fusion."""
    seq = tiny_seq
    flags = _flags(None)        # conservatively all-active
    dt = jnp.float32(seq.dt / seq.imu_per_frame)
    step = jax.jit(lambda st, *a: localize_step(st, *a, **bind))

    def drive(mode):
        st = init_localizer_state(tiny_cfg, WINDOW, p0=seq.poses[0][:3, 3])
        outs = None
        for i in range(5):
            il, ir, a, g = _frame_args(seq, i)
            st, outs = step(st, il, ir, a, g, jnp.asarray(seq.gps[i]),
                            jnp.int32(mode), flags, dt)
        return st, outs

    st_bad, outs_bad = drive(99)
    st_reg, outs_reg = drive(MODE_REGISTRATION)
    _assert_trees_equal(st_bad, st_reg)       # spine-only == spine-only
    assert not np.asarray(outs_bad.ba_ran)
    assert float(np.asarray(outs_bad.hist).sum()) == 0.0
    # ...and it is NOT the VIO branch (GPS was valid: VIO would fuse it)
    st_vio, _ = drive(MODE_VIO)
    assert not np.allclose(np.asarray(st_bad.filt.p),
                           np.asarray(st_vio.filt.p))


# --------------------------------------------------------------------------
# extensibility: a new scenario without touching step.py
# --------------------------------------------------------------------------

def test_register_scenario_without_touching_step(tiny_cfg, tiny_seq):
    """The worked README example: register a spec, build localizers
    AFTER, and the compiled program grows a branch — no step.py edit,
    one trace, behavior distinct from the base scenario."""
    from repro.core.fleet import FleetLocalizer
    seq = tiny_seq
    spec = scen.ScenarioSpec(
        name="vio_tight",
        pipeline=scen.SPINE + (scen.use("gps_fusion", sigma_gps=0.005),),
        env_rule=scen.EnvRule(gps=True, degraded=False, priority=25))
    mid = scen.register_scenario(spec)
    try:
        assert mid == 5
        assert scen.table().specs[mid].name == "vio_tight"
        # priority 25 beats the shipped vio rule (20): clean-GPS
        # environments now resolve to the new profile, degraded ones
        # still to vio_degraded
        assert scen.table().resolve_env(Environment(True, False)) == mid
        assert scen.table().resolve_env(
            Environment(True, False, gps_degraded=True)) == 4
        B, T = 2, 6
        il, ir, ac, gy, gps = frames.tile_fleet_sequence(seq, B, T)
        fleet = FleetLocalizer(tiny_cfg, seq.cam, batch=B, window=WINDOW)
        states = fleet.init_state(p0=np.tile(seq.poses[0][:3, 3], (B, 1)))
        states = fleet.run(states, il, ir, ac, gy, gps,
                           np.array([MODE_VIO, mid], np.int32),
                           seq.dt / seq.imu_per_frame, chunk=3)
        assert fleet.chunk_trace_count() == 1
        pos = fleet.positions(states)
        assert np.all(np.isfinite(pos))
        # same inputs, different fusion sigma -> different estimates
        assert not np.allclose(pos[0], pos[1])
    finally:
        scen.unregister_scenario("vio_tight")


def test_per_scenario_gated_knob_lookup(tiny_cfg, tiny_seq, bind):
    """A registered scenario with a different BA cadence shares the
    gated block through a baked per-mode lookup table: slam_fast (use-
    level ba_every=1) runs BA on frames the shipped slam (cadence 2)
    skips, in the SAME compiled program."""
    spec = scen.ScenarioSpec(
        name="slam_fast",
        pipeline=scen.SPINE + (scen.use("bow_histogram"),
                               scen.use("ba_marginalize", ba_every=1)),
        host_stage="slam")
    mid = scen.register_scenario(spec)
    try:
        seq = tiny_seq
        flags = flags_from_plan(sched.OffloadPlan(marg_schur=False),
                                modes=(MODE_SLAM, mid))
        dt = jnp.float32(seq.dt / seq.imu_per_frame)
        step = jax.jit(lambda st, *a: localize_step(st, *a, **bind))

        def ba_rans(mode):
            st = init_localizer_state(tiny_cfg, WINDOW,
                                      p0=seq.poses[0][:3, 3])
            rans = []
            for i in range(8):
                il, ir, a, g = _frame_args(seq, i)
                st, outs = step(st, il, ir, a, g, jnp.asarray(seq.gps[i]),
                                jnp.int32(mode), flags, dt)
                rans.append(bool(np.asarray(outs.ba_ran)))
            return rans

        fast, slow = ba_rans(mid), ba_rans(MODE_SLAM)
        assert sum(fast) > sum(slow) > 0, (fast, slow)
    finally:
        scen.unregister_scenario("slam_fast")


def test_unregister_non_tail_raises():
    with pytest.raises(ValueError, match="last-registered"):
        scen.unregister_scenario("vio")


def test_unknown_host_stage_rejected():
    with pytest.raises(ValueError, match="host_stage"):
        scen.register_scenario(scen.ScenarioSpec(
            name="bad_stage", pipeline=scen.SPINE, host_stage="mapping"))
    assert "bad_stage" not in scen.SCENARIOS


def test_spine_contract_enforced():
    with pytest.raises(ValueError, match="spine"):
        scen.register_scenario(scen.ScenarioSpec(
            name="broken", pipeline=(scen.use("frontend"),
                                     scen.use("gps_fusion"),
                                     scen.use("track_ring"))))
    assert "broken" not in scen.SCENARIOS


# --------------------------------------------------------------------------
# taxonomy + plan/flags generalization
# --------------------------------------------------------------------------

def test_select_mode_id_extended_taxonomy():
    ids = select_mode_id(
        np.array([False, False, True, True, False, True]),
        np.array([False, True, False, True, False, False]),
        gps_degraded=np.array([False, False, False, False, False, True]),
        airborne=np.array([False, False, False, False, True, False]))
    np.testing.assert_array_equal(
        np.asarray(ids), [MODE_SLAM, MODE_REGISTRATION, MODE_VIO, MODE_VIO,
                          MODE_DRONE_VIO, MODE_VIO_DEGRADED])


def test_offload_plan_keyed_by_primitive_name():
    lm = sched.LatencyModels(transfer_bw=1e12, fixed_overhead_s=0.0)
    sizes = np.linspace(16, 4096, 16)
    host = 1e-6 * sizes
    lm.fit_kernel("kalman_gain", sizes, host, host * 0.1)
    lm.fit_kernel("marginalization", sizes, host, host * 10.0)
    plan = lm.plan_frame(window=8, max_updates=24, ba_landmarks=64)
    # primitive-name keys...
    assert plan["msckf_update"] is True or plan["msckf_update"] is False
    assert plan["msckf_update"] and not plan["ba_marginalize"]
    assert set(sched.PLAN_KEYS) <= set(plan)
    # ...legacy attribute aliases read the same decisions
    assert plan.kalman_gain == plan["msckf_update"]
    assert plan.marginalization == plan["ba_marginalize"]
    # replace() round-trips both spellings
    assert not plan.replace(msckf_update=False).kalman_gain
    assert not plan.replace(kalman_gain=False)["msckf_update"]
    # unknown primitives default to offload
    assert plan.get("future_primitive") is True


def test_flags_activity_from_modes():
    flags = _flags((MODE_VIO, MODE_DRONE_VIO))
    assert not bool(flags.active["slam"])
    assert bool(flags.active["vio"]) and bool(flags.active["drone_vio"])
    assert not bool(flags.slam)
    # legacy views still read the per-primitive gates
    assert bool(flags.kalman) and bool(flags.marg)
    assert not bool(flags.marg_pallas)


def test_flags_drop_megakernel_gates_when_off():
    """A megakernel selector decided off host-side must be ABSENT from
    the gate dict — its lax.cond would otherwise be traced, and even an
    untaken fused branch perturbs XLA fusion under vmap enough to break
    bitwise fleet/monolith parity. On (or traced) keys survive."""
    off = flags_from_plan(sched.OffloadPlan(marg_schur=False))
    assert "frontend_fused" not in off.gates
    assert "cov_update" not in off.gates
    assert "marg_schur" in off.gates  # work gates always stay traced

    on = flags_from_plan(sched.OffloadPlan(frontend_fused=True,
                                           cov_update=True))
    assert bool(on.gates["frontend_fused"]) and bool(on.gates["cov_update"])

    traced = flags_from_plan({"frontend_fused": jnp.asarray(False)})
    assert "frontend_fused" in traced.gates
