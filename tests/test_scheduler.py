"""Runtime scheduler (paper Sec. VI-B): regression fit quality + offload
decision structure."""
import numpy as np

from repro.core.scheduler import (KERNEL_MODELS, LatencyModels,
                                  RegressionModel, VariationTracker)


def test_linear_fit_r2():
    sizes = np.linspace(100, 4000, 40)
    times = 2e-6 * sizes + 1e-4 + np.random.RandomState(0).randn(40) * 5e-5
    m = RegressionModel(1).fit(sizes, times)
    assert m.r2 > 0.9
    assert abs(m.predict(2000) - (2e-6 * 2000 + 1e-4)) < 3e-4


def test_quadratic_fit_r2():
    sizes = np.linspace(50, 600, 40)
    times = 3e-8 * sizes ** 2 + 1e-4
    times += np.random.RandomState(0).randn(40) * np.ptp(times) * 0.02
    m = RegressionModel(2).fit(sizes, times)
    assert m.r2 > 0.95, "paper reports R^2 = 0.82-0.98"


def test_offload_decision_crossover():
    """Small matrices stay on host (transfer dominates); large offload."""
    lm = LatencyModels(transfer_bw=1e9, fixed_overhead_s=1e-3)
    sizes = np.linspace(50, 2000, 30)
    host = 5e-9 * sizes ** 2          # host quadratic
    accel = 2e-10 * sizes ** 2        # accel 25x faster
    lm.fit_kernel("kalman_gain", sizes, host, accel)
    assert not lm.should_offload("kalman_gain", 60, transfer_bytes=10_000)
    assert lm.should_offload("kalman_gain", 2000, transfer_bytes=10_000)


def test_offload_monotone_in_transfer():
    lm = LatencyModels(transfer_bw=1e9, fixed_overhead_s=0.0)
    sizes = np.linspace(50, 2000, 30)
    lm.fit_kernel("projection", sizes, 1e-6 * sizes, 1e-8 * sizes)
    assert lm.should_offload("projection", 1000, transfer_bytes=0)
    assert not lm.should_offload("projection", 1000,
                                 transfer_bytes=10 ** 9)


def test_default_offload_without_model():
    assert LatencyModels().should_offload("marginalization", 100)


def test_variation_tracker():
    t = VariationTracker()
    for x in [0.01, 0.012, 0.011, 0.04]:
        t.add(x)
    s = t.stats()
    assert s["worst_over_best"] > 3.0
    assert 0 < s["rsd"] < 1.0


def test_kernel_model_degrees_match_paper():
    # Fig. 16: projection linear; kalman gain / marginalization quadratic
    assert KERNEL_MODELS["projection"] == 1
    assert KERNEL_MODELS["kalman_gain"] == 2
    assert KERNEL_MODELS["marginalization"] == 2
