"""Runtime scheduler (paper Sec. VI-B): regression fit quality + offload
decision structure."""
import numpy as np
import pytest

from repro.core.scheduler import (KERNEL_MODELS, LatencyModels,
                                  RegressionModel, VariationTracker)


def test_linear_fit_r2():
    sizes = np.linspace(100, 4000, 40)
    times = 2e-6 * sizes + 1e-4 + np.random.RandomState(0).randn(40) * 5e-5
    m = RegressionModel(1).fit(sizes, times)
    assert m.r2 > 0.9
    assert abs(m.predict(2000) - (2e-6 * 2000 + 1e-4)) < 3e-4


def test_quadratic_fit_r2():
    sizes = np.linspace(50, 600, 40)
    times = 3e-8 * sizes ** 2 + 1e-4
    times += np.random.RandomState(0).randn(40) * np.ptp(times) * 0.02
    m = RegressionModel(2).fit(sizes, times)
    assert m.r2 > 0.95, "paper reports R^2 = 0.82-0.98"


def test_offload_decision_crossover():
    """Small matrices stay on host (transfer dominates); large offload."""
    lm = LatencyModels(transfer_bw=1e9, fixed_overhead_s=1e-3)
    sizes = np.linspace(50, 2000, 30)
    host = 5e-9 * sizes ** 2          # host quadratic
    accel = 2e-10 * sizes ** 2        # accel 25x faster
    lm.fit_kernel("kalman_gain", sizes, host, accel)
    assert not lm.should_offload("kalman_gain", 60, transfer_bytes=10_000)
    assert lm.should_offload("kalman_gain", 2000, transfer_bytes=10_000)


def test_offload_monotone_in_transfer():
    lm = LatencyModels(transfer_bw=1e9, fixed_overhead_s=0.0)
    sizes = np.linspace(50, 2000, 30)
    lm.fit_kernel("projection", sizes, 1e-6 * sizes, 1e-8 * sizes)
    assert lm.should_offload("projection", 1000, transfer_bytes=0)
    assert not lm.should_offload("projection", 1000,
                                 transfer_bytes=10 ** 9)


def test_default_offload_without_model():
    assert LatencyModels().should_offload("marginalization", 100)


def test_variation_tracker():
    t = VariationTracker()
    for x in [0.01, 0.012, 0.011, 0.04]:
        t.add(x)
    s = t.stats()
    assert s["worst_over_best"] > 3.0
    assert 0 < s["rsd"] < 1.0


def test_kernel_model_degrees_match_paper():
    # Fig. 16: projection linear; kalman gain / marginalization quadratic
    assert KERNEL_MODELS["projection"] == 1
    assert KERNEL_MODELS["kalman_gain"] == 2
    assert KERNEL_MODELS["marginalization"] == 2


# --------------------------------------------------------------------------
# degenerate-input guards
# --------------------------------------------------------------------------

def test_fit_single_sample_is_nan_free():
    """One profile point can't constrain a quadratic: the model must
    degrade to a finite constant with r2 = 0, not a -inf/NaN polyfit."""
    m = RegressionModel(2).fit(np.array([100.0]), np.array([1e-3]))
    assert m.r2 == 0.0
    assert np.isfinite(m.predict(50)) and np.isfinite(m.predict(5000))
    assert m.predict(123) == 1e-3


def test_fit_empty_profile_stays_unfitted():
    """Zero usable samples (empty sweep, or all non-finite) must leave
    the model unfitted so the offload-by-default path applies — not a
    'fitted' constant-0 that pins every decision to the host."""
    m = RegressionModel(1).fit(np.array([]), np.array([]))
    assert not m.fitted and m.r2 == 0.0
    m2 = RegressionModel(2).fit(np.array([np.nan, np.inf]),
                                np.array([1e-4, 2e-4]))
    assert not m2.fitted
    lm = LatencyModels()
    lm.host["projection"] = m
    lm.accel["projection"] = m
    assert not lm.fitted("projection")
    assert lm.should_offload("projection", 100)


def test_fit_repeated_size_is_nan_free():
    """All samples at one size: zero spread, constant fallback."""
    m = RegressionModel(1).fit(np.full(8, 64.0), np.linspace(1e-4, 2e-4, 8))
    assert m.r2 == 0.0
    assert np.isfinite(m.predict(64))


def test_fit_constant_times_r2():
    """Perfectly constant latency is a perfect (if trivial) fit, not a
    0/0 explosion."""
    m = RegressionModel(1).fit(np.linspace(10, 100, 10), np.full(10, 5e-4))
    assert m.r2 == 1.0
    assert m.predict(55) == pytest.approx(5e-4)


def test_fit_drops_non_finite_samples():
    sizes = np.array([10.0, 20.0, 30.0, 40.0, np.nan, 60.0])
    times = np.array([1e-4, 2e-4, 3e-4, 4e-4, 5e-4, np.inf])
    m = RegressionModel(1).fit(sizes, times)
    assert np.isfinite(m.r2)
    assert np.isfinite(m.predict(25))


def test_should_offload_half_fitted_defaults_true():
    """A kernel with only one side profiled (or a degenerate fit with no
    coefficients) must take the unfitted default, not crash in
    predict()."""
    lm = LatencyModels()
    lm.host["kalman_gain"] = RegressionModel(2)      # never .fit()
    assert not lm.fitted("kalman_gain")
    assert lm.should_offload("kalman_gain", 100, transfer_bytes=0)


def test_should_offload_zero_transfer_unfitted():
    assert LatencyModels().should_offload("projection", 10,
                                          transfer_bytes=0)


def test_should_offload_zero_bandwidth_guard():
    """transfer_bw = 0 (unknown link) must not divide by zero."""
    lm = LatencyModels(transfer_bw=0.0, fixed_overhead_s=0.0)
    sizes = np.linspace(10, 100, 10)
    lm.fit_kernel("projection", sizes, 1e-4 * sizes, 1e-6 * sizes)
    assert lm.should_offload("projection", 50, transfer_bytes=1000)


def test_variation_tracker_single_sample():
    t = VariationTracker()
    t.add(0.01)
    s = t.stats()
    assert s == {"mean": 0.01, "sd": 0.0, "rsd": 0.0,
                 "worst_over_best": 1.0}
    assert all(np.isfinite(v) for v in s.values())


def test_variation_tracker_ignores_non_finite():
    t = VariationTracker()
    for x in [0.01, float("nan"), 0.02, float("inf")]:
        t.add(x)
    s = t.stats()
    assert all(np.isfinite(v) for v in s.values())
    assert s["mean"] == pytest.approx(0.015)


# --------------------------------------------------------------------------
# per-chunk plan resolution
# --------------------------------------------------------------------------

def test_plan_frame_covers_all_paper_kernels():
    plan = LatencyModels().plan_frame(window=8, max_updates=24,
                                      map_points=2048, ba_landmarks=64)
    # unfitted models: offload-by-default on every kernel
    assert plan.kalman_gain and plan.projection and plan.marginalization
    assert plan.frontend


def test_plan_chunk_amortizes_fixed_overhead():
    """A kernel on the edge of profitability at K=1 (launch overhead
    dominates) becomes profitable once the dispatch is amortized over a
    chunk."""
    lm = LatencyModels(transfer_bw=1e12, fixed_overhead_s=1e-3)
    sizes = np.linspace(50, 2000, 30)
    host = 1e-6 * sizes                      # 384us at the plan size
    accel = 0.5e-6 * sizes                   # wins on compute...
    lm.fit_kernel("kalman_gain", sizes, host, accel)
    h = 24 * 2 * 8                           # plan size for window=8
    # at K=1 the 1ms launch overhead swamps the ~0.2ms compute win
    assert not lm.plan_frame(window=8, max_updates=24).kalman_gain
    assert lm.plan_chunk(window=8, max_updates=24, chunk=8).kalman_gain
