"""Frontend validation against synthetic ground truth: FAST finds the
rendered landmarks, stereo disparity and LK flow match geometry."""
import dataclasses

import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.eudoxus import EDX_DRONE
from repro.core.frontend import fast
from repro.core.frontend.pipeline import run_frontend


@pytest.fixture(scope="module")
def fe_cfg():
    return dataclasses.replace(EDX_DRONE.frontend, height=120, width=160,
                               max_features=128)


def gt_projections(seq, frame):
    cam = seq.cam
    R = seq.poses[frame][:3, :3]
    t = seq.poses[frame][:3, 3]
    pw = (seq.landmarks - t) @ R
    z = pw[:, 2]
    u = cam.fx * pw[:, 0] / np.maximum(z, 1e-6) + cam.cx
    v = cam.fy * pw[:, 1] / np.maximum(z, 1e-6) + cam.cy
    vis = (z > 0.5) & (u > 4) & (u < 156) & (v > 4) & (v < 116)
    return u, v, z, vis


def test_fast_detects_landmarks(synthetic_sequence, fe_cfg):
    seq = synthetic_sequence
    r = run_frontend(jnp.asarray(seq.images_left[0]),
                     jnp.asarray(seq.images_right[0]), fe_cfg)
    n_valid = int(r.valid.sum())
    assert n_valid >= 40, "should detect a healthy share of rendered blobs"
    u, v, z, vis = gt_projections(seq, 0)
    yx = np.asarray(r.yx)[np.asarray(r.valid)]
    dists = []
    for y, x in yx:
        d = np.hypot(u[vis] - x, v[vis] - y).min()
        dists.append(d)
    assert np.median(dists) < 2.0, "features should sit on landmarks"


def test_stereo_disparity_accuracy(synthetic_sequence, fe_cfg):
    seq = synthetic_sequence
    cam = seq.cam
    r = run_frontend(jnp.asarray(seq.images_left[0]),
                     jnp.asarray(seq.images_right[0]), fe_cfg)
    u, v, z, vis = gt_projections(seq, 0)
    sv = np.asarray(r.stereo_valid)
    assert sv.sum() >= 25
    yx = np.asarray(r.yx)
    disp = np.asarray(r.disparity)
    errs = []
    for i in np.nonzero(sv)[0]:
        j = np.argmin(np.hypot(u[vis] - yx[i, 1], v[vis] - yx[i, 0]))
        if np.hypot(u[vis][j] - yx[i, 1], v[vis][j] - yx[i, 0]) < 2:
            errs.append(abs(cam.fx * cam.baseline / z[vis][j] - disp[i]))
    assert np.median(errs) < 1.0, f"median disparity error {np.median(errs)}"


def test_lk_tracking_accuracy(synthetic_sequence, fe_cfg):
    seq = synthetic_sequence
    il0 = jnp.asarray(seq.images_left[0])
    r0 = run_frontend(il0, jnp.asarray(seq.images_right[0]), fe_cfg)
    feats0 = fast.Features(yx=r0.yx, score=r0.score, valid=r0.valid)
    r1 = run_frontend(jnp.asarray(seq.images_left[1]),
                      jnp.asarray(seq.images_right[1]), fe_cfg, il0, feats0)
    tv = np.asarray(r1.track_valid)
    assert tv.sum() >= 25
    u0, v0, _, vis0 = gt_projections(seq, 0)
    u1, v1, _, _ = gt_projections(seq, 1)
    yx0 = np.asarray(r0.yx)
    ty = np.asarray(r1.prev_yx)
    errs = []
    for i in np.nonzero(tv)[0]:
        j = np.argmin(np.hypot(u0[vis0] - yx0[i, 1], v0[vis0] - yx0[i, 0]))
        if np.hypot(u0[vis0][j] - yx0[i, 1], v0[vis0][j] - yx0[i, 0]) < 2:
            errs.append(np.hypot(u1[vis0][j] - ty[i, 1], v1[vis0][j] - ty[i, 0]))
    assert np.median(errs) < 1.0, f"median flow error {np.median(errs)}"


def test_descriptor_stability(synthetic_sequence, fe_cfg):
    """Same feature across L/R views should have small hamming distance."""
    seq = synthetic_sequence
    r = run_frontend(jnp.asarray(seq.images_left[0]),
                     jnp.asarray(seq.images_right[0]), fe_cfg)
    # matched stereo pairs passed the hamming budget by construction
    assert int(r.stereo_valid.sum()) >= 25
