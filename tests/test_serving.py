"""Serving-layer invariants: the paged robot-state pool and the
chunk-boundary admission engine (``repro.serve``).

The load-bearing claims, each pinned here:
  * churn is a slot-table write — arbitrary join/leave/swap sequences
    keep the slot table consistent (hypothesis fuzz) and the chunk
    program at ONE trace;
  * generation counters make recycled slots safe — a ticket held across
    its robot's departure raises instead of reading the next occupant;
  * a churned pool is BITWISE equal to a statically-constructed pool of
    the surviving robots fed the same per-robot streams (same capacity,
    same slots — the padded-batch discipline only promises bitwise
    equality within one layout);
  * the engine mutates the pool at chunk boundaries only, and the
    overflow path (elastic resize) carries state and is counted apart.
"""
import dataclasses

import numpy as np
import pytest

from repro.core.environment import MODE_SLAM, MODE_VIO
from repro.launch.watchdog import StepTimeTracker
from repro.serve import (PoolFull, RobotStatePool, ServingEngine,
                         StaleGeneration, UnknownRobot)


@pytest.fixture(scope="module")
def bookkeeping_pool(synthetic_sequence, small_cfg):
    """One capacity-4 pool shared by every test that never dispatches a
    chunk — admission/departure/tickets are host-side slot-table writes,
    so reusing the pool costs nothing and saves a fleet build per test."""
    return RobotStatePool(small_cfg, synthetic_sequence.cam, capacity=4,
                          window=8)


def _drain(pool):
    for rid in list(pool.robot_ids):
        pool.retire(rid)


def _robot_frames(seq, i0, n):
    ipf = seq.imu_per_frame
    ac = np.stack([seq.imu_accel[max(i - 1, 0) * ipf:max(i, 1) * ipf]
                   for i in range(i0, i0 + n)])
    gy = np.stack([seq.imu_gyro[max(i - 1, 0) * ipf:max(i, 1) * ipf]
                   for i in range(i0, i0 + n)])
    return (seq.images_left[i0:i0 + n], seq.images_right[i0:i0 + n],
            ac, gy, seq.gps[i0:i0 + n])


# ---------------------------------------------------------------------------
# slot-table bookkeeping (no chunk dispatches)
# ---------------------------------------------------------------------------
def test_admit_retire_recycles_slots(bookkeeping_pool):
    pool = bookkeeping_pool
    _drain(pool)
    t1 = pool.admit("a")
    t2 = pool.admit("b", "slam")
    assert (t1.slot, t2.slot) != (None, None) and t1.slot != t2.slot
    assert pool.occupancy == 2 and pool.free_slots == pool.capacity - 2
    assert pool.mode_of("b") == MODE_SLAM
    pool.retire("a")
    t3 = pool.admit("c")
    # lowest free index is reused, at a bumped generation
    assert t3.slot == t1.slot and t3.generation == t1.generation + 1
    pool.check_invariants()
    with pytest.raises(ValueError):
        pool.admit("c")                      # double admission
    with pytest.raises(UnknownRobot):
        pool.slot_of("a")                    # departed


def test_stale_generation_reads_raise(bookkeeping_pool):
    pool = bookkeeping_pool
    _drain(pool)
    tk = pool.admit("r", p0=np.array([1.0, 2.0, 3.0]))
    assert np.allclose(pool.position(tk), [1.0, 2.0, 3.0])
    pool.retire("r")
    pool.admit("other", slot=tk.slot, p0=np.array([9.0, 9.0, 9.0]))
    # the slot is live again with a NEW occupant: the old ticket must
    # raise, never return robot "other"'s state
    with pytest.raises(StaleGeneration):
        pool.position(tk)
    with pytest.raises(StaleGeneration):
        pool.state_row(tk)


def test_pool_full_and_explicit_slots(bookkeeping_pool):
    pool = bookkeeping_pool
    _drain(pool)
    pool.admit("x", slot=2)
    assert pool.slot_of("x") == 2
    with pytest.raises(ValueError):
        pool.admit("y", slot=2)              # not free
    for i in range(pool.capacity - 1):
        pool.admit(f"f{i}")
    with pytest.raises(PoolFull):
        pool.admit("overflow")
    pool.check_invariants()


def test_assign_scenario_is_a_table_write(bookkeeping_pool):
    pool = bookkeeping_pool
    _drain(pool)
    pool.admit("r", "vio")
    pool.assign_scenario("r", "slam")
    assert pool.mode_of("r") == MODE_SLAM
    assert pool.scenario_swaps >= 1
    with pytest.raises(ValueError):
        pool.assign_scenario("r", "no-such-scenario")


def _churn_property(pool, seq):
    """The churn invariant: after EVERY operation the slot table and
    free list partition [0, C), live tickets match their slots'
    generations, and tickets retired along the way raise."""
    _drain(pool)
    live, dead = {}, []
    for kind, rid, scen in seq:
        if kind == "join" and rid not in live:
            try:
                live[rid] = pool.admit(rid, scen)
            except PoolFull:
                assert pool.free_slots == 0
        elif kind == "leave" and rid in live:
            pool.retire(rid)
            dead.append(live.pop(rid))
        elif kind == "swap" and rid in live:
            pool.assign_scenario(rid, scen)
        pool.check_invariants()
    assert set(pool.robot_ids) == set(live)
    for rid, tk in live.items():
        assert pool.position(tk).shape == (3,)
    for tk in dead:
        with pytest.raises(StaleGeneration):
            pool.position(tk)


def test_churn_fuzz_slot_table_consistency(bookkeeping_pool):
    """Random join/leave/swap churn fuzzing — hypothesis-driven when
    available (shrinking on failure), seeded numpy sequences otherwise
    so the property is exercised on every box."""
    try:
        from hypothesis import given, settings, strategies as st
    except ImportError:
        rng = np.random.RandomState(0)
        kinds = ["join", "leave", "swap"]
        scens = ["vio", "slam"]
        for _ in range(25):
            seq = [(kinds[rng.randint(3)], int(rng.randint(6)),
                    scens[rng.randint(2)])
                   for _ in range(rng.randint(1, 25))]
            _churn_property(bookkeeping_pool, seq)
        return

    ops = st.lists(st.tuples(st.sampled_from(["join", "leave", "swap"]),
                             st.integers(0, 5),
                             st.sampled_from(["vio", "slam"])),
                   min_size=1, max_size=24)

    @settings(max_examples=25, deadline=None)
    @given(ops)
    def run(seq):
        _churn_property(bookkeeping_pool, seq)

    run()


def test_active_mask_cache_and_2d_validation(bookkeeping_pool):
    fleet = bookkeeping_pool.fleet
    a1, n1 = fleet._active_mask(4, None)
    a2, n2 = fleet._active_mask(4, None)
    assert a1 is a2 and n1 == n2 == 4      # cached, not rebuilt
    assert not a1.flags.writeable          # shared across dispatches
    counts = np.array([2, 0, 3, 1])
    m = np.arange(3)[:, None] < counts[None, :]
    act, n_real = fleet._active_mask(3, m)
    assert n_real == 3 and act.shape == (3, fleet.padded)
    assert np.array_equal(act[:, :4], m)
    bad = m.copy()
    bad[0, 0], bad[1, 0] = False, True     # hole: not a prefix
    with pytest.raises(ValueError):
        fleet._active_mask(3, bad)
    with pytest.raises(ValueError):
        fleet._active_mask(3, m[:, :2])    # wrong width


# ---------------------------------------------------------------------------
# engine semantics (no chunk dispatches)
# ---------------------------------------------------------------------------
def test_engine_mutates_only_at_chunk_boundaries(bookkeeping_pool):
    pool = bookkeeping_pool
    _drain(pool)
    eng = ServingEngine(pool, chunk=2, overflow="reject")
    eng.submit_join("a")
    eng.submit_join("b", "slam")
    eng.submit_leave("a")
    assert pool.occupancy == 0 and eng.pending_requests() == 3
    eng.run_chunk()                        # the single drain point
    assert eng.pending_requests() == 0
    assert set(pool.robot_ids) == {"b"} and pool.mode_of("b") == MODE_SLAM
    assert pool.admissions >= 2 and pool.departures >= 1


def test_engine_reject_overflow(synthetic_sequence, small_cfg):
    pool = RobotStatePool(small_cfg, synthetic_sequence.cam, capacity=1,
                          window=8)
    eng = ServingEngine(pool, chunk=2, overflow="reject")
    eng.submit_join("a")
    eng.submit_join("b")
    eng.run_chunk()
    assert pool.occupancy == 1 and eng.rejected == 1
    assert pool.capacity == 1 and pool.resizes == 0


def test_engine_resize_overflow_carries_state(synthetic_sequence,
                                              small_cfg):
    pool = RobotStatePool(small_cfg, synthetic_sequence.cam, capacity=1,
                          window=8)
    eng = ServingEngine(pool, chunk=2, overflow="resize")
    eng.submit_join("a", p0=np.array([1.0, 2.0, 3.0]))
    eng.run_chunk()
    eng.submit_join("b", p0=np.array([4.0, 5.0, 6.0]))
    eng.run_chunk()                        # forces the slow path
    assert pool.capacity == 2 and pool.resizes == 1
    assert pool.retired_chunk_traces == 0  # nothing dispatched yet
    # robot a's row crossed pools intact; slots/tickets preserved
    assert np.allclose(pool.position(eng.tickets["a"]), [1.0, 2.0, 3.0])
    assert np.allclose(pool.position(eng.tickets["b"]), [4.0, 5.0, 6.0])
    pool.check_invariants()
    with pytest.raises(ValueError):
        pool.resize(2)                     # no-op resize refused


# ---------------------------------------------------------------------------
# shrink-on-idle: the downward resize (PR 10)
# ---------------------------------------------------------------------------
def test_pool_shrink_carries_state_bitwise(synthetic_sequence, small_cfg):
    pool = RobotStatePool(small_cfg, synthetic_sequence.cam, capacity=4,
                          window=8)
    tk = pool.admit("a", p0=np.array([1.0, 2.0, 3.0]))
    row_before = pool.state_row(tk)
    pool.resize(2)
    assert pool.capacity == 2 and pool.resizes == 1
    assert pool.free_slots == 1
    row_after = pool.state_row(tk)
    before, after = _tree_leaves_pair(row_before, row_after)
    assert len(before) == len(after)
    for b, a in zip(before, after):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    pool.check_invariants()
    # the freed high slots are really gone: the pool refills to 2, not 4
    pool.admit("b")
    with pytest.raises(PoolFull):
        pool.admit("c")


def _tree_leaves_pair(a, b):
    import jax
    return (jax.tree_util.tree_leaves(a), jax.tree_util.tree_leaves(b))


def test_pool_shrink_refusals(synthetic_sequence, small_cfg):
    pool = RobotStatePool(small_cfg, synthetic_sequence.cam, capacity=4,
                          window=8)
    pool.admit("hi", slot=3)
    # a bound slot above the new capacity pins it (slots never relocate)
    with pytest.raises(ValueError):
        pool.resize(2)
    pool.retire("hi")
    pool.admit("lo", slot=0)
    # chunks in flight pin it too: the staging capacity axis dies with
    # the old pool
    fl = pool.dispatch_chunk({"lo": _robot_frames(synthetic_sequence,
                                                  0, 2)},
                             dt_imu=0.005, chunk=2)
    from repro.serve.pool import StagingOverrun
    with pytest.raises(StagingOverrun):
        pool.resize(2)
    pool.drain_chunk(fl)
    pool.resize(2)
    assert pool.capacity == 2 and pool.retired_chunk_traces == 1
    pool.check_invariants()


def test_engine_shrink_on_idle(synthetic_sequence, small_cfg):
    pool = RobotStatePool(small_cfg, synthetic_sequence.cam, capacity=4,
                          window=8)
    eng = ServingEngine(pool, chunk=2, shrink_after=2,
                        shrink_low_water=0.3)
    eng.submit_join("a", p0=np.array([7.0, 8.0, 9.0]))
    eng.run_chunk()
    # occupancy 1/4 <= 0.3*4: low-water, but not for long enough yet
    assert pool.capacity == 4 and eng.shrinks == 0
    eng.run_chunk()
    eng.run_chunk()
    # after shrink_after consecutive idle boundaries: halved, state kept
    assert pool.capacity == 2 and eng.shrinks == 1
    assert np.allclose(pool.position(eng.tickets["a"]), [7.0, 8.0, 9.0])
    # occupancy 1/2 > 0.3*2: no further shrink, the counter resets
    eng.run_chunk()
    eng.run_chunk()
    eng.run_chunk()
    assert pool.capacity == 2 and eng.shrinks == 1
    assert eng.latency_report()["pool"]["shrinks"] == 1
    pool.check_invariants()


def test_engine_shrink_default_off(bookkeeping_pool):
    pool = bookkeeping_pool
    _drain(pool)
    eng = ServingEngine(pool, chunk=2)
    for _ in range(8):                     # empty pool, many boundaries
        eng.run_chunk()
    assert pool.capacity == 4 and eng.shrinks == 0
    with pytest.raises(ValueError):
        ServingEngine(pool, shrink_after=0)
    with pytest.raises(ValueError):
        ServingEngine(pool, shrink_after=2, shrink_low_water=1.5)
    with pytest.raises(ValueError):
        ServingEngine(pool, shrink_after=2, shrink_min_capacity=0)


def test_tracker_snapshot_is_non_resetting():
    tr = StepTimeTracker()
    for v in (0.1, 0.2, 0.3, float("nan")):
        tr.add(v)
    s1 = tr.snapshot()
    assert s1["count"] == 3 and s1["p50"] == pytest.approx(0.2)
    assert s1["p99"] == pytest.approx(0.298)
    s2 = tr.snapshot()
    assert s2 == s1                        # reporting twice changes nothing
    assert len(tr.samples) == 4            # samples untouched (NaN kept raw)
    tr.add(0.4)
    assert tr.snapshot()["count"] == 4


# ---------------------------------------------------------------------------
# the flagship equivalence: churned pool == static pool, bitwise
# ---------------------------------------------------------------------------
def test_churned_pool_bitwise_equals_static(synthetic_sequence, small_cfg):
    """Admit A+B, run a chunk, retire B, admit C into B's recycled slot,
    run another chunk — the survivors' state rows must be BITWISE equal
    to a pool that held A and C from the start (C inactive until its
    admission chunk), and the churned pool's chunk program must have
    traced exactly once."""
    seq = synthetic_sequence
    dt = seq.dt / seq.imu_per_frame
    v0 = (seq.poses[1][:3, 3] - seq.poses[0][:3, 3]) / seq.dt

    def fresh_pool():
        return RobotStatePool(small_cfg, seq.cam, capacity=2, window=8)

    # --- churned lifetime ---
    churned = fresh_pool()
    churned.admit("A", "vio", p0=seq.poses[0][:3, 3], v0=v0, slot=0)
    tb = churned.admit("B", "slam", p0=seq.poses[0][:3, 3], v0=v0, slot=1)
    churned.step_chunk({"A": _robot_frames(seq, 0, 2),
                        "B": _robot_frames(seq, 0, 2)}, dt, chunk=2)
    churned.retire("B")
    tc = churned.admit("C", "slam", p0=seq.poses[0][:3, 3], v0=v0)
    assert tc.slot == tb.slot              # recycled
    churned.step_chunk({"A": _robot_frames(seq, 2, 2),
                        "C": _robot_frames(seq, 0, 2)}, dt, chunk=2)
    assert churned.chunk_trace_count() == 1    # zero retraces across churn
    assert churned.admissions == 3 and churned.departures == 1

    # --- static fleet of the survivors, same slots, same streams ---
    static = fresh_pool()
    static.admit("A", "vio", p0=seq.poses[0][:3, 3], v0=v0, slot=0)
    static.admit("C", "slam", p0=seq.poses[0][:3, 3], v0=v0, slot=1)
    static.step_chunk({"A": _robot_frames(seq, 0, 2)}, dt, chunk=2)
    static.step_chunk({"A": _robot_frames(seq, 2, 2),
                       "C": _robot_frames(seq, 0, 2)}, dt, chunk=2)
    assert static.chunk_trace_count() == 1

    for rid in ("A", "C"):
        a = churned.state_row(churned.ticket_of(rid))
        b = static.state_row(static.ticket_of(rid))
        for name in ("p", "v", "q", "P"):
            assert np.array_equal(getattr(a.filt, name),
                                  getattr(b.filt, name)), (rid, name)
        assert np.array_equal(a.tracks_uv, b.tracks_uv), rid
        assert np.array_equal(a.tracks_valid, b.tracks_valid), rid
        assert np.array_equal(a.frame_idx, b.frame_idx), rid
