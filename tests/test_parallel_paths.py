"""Numeric equivalence of the distribution-optimized execution paths
against their plain-math references (the §Perf hillclimb changes)."""
import subprocess
import sys
import textwrap
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.lm import get_config, reduced
from repro.models import model
from repro.models.attention import _chunked_attention, _einsum_attention

SRC = str(Path(__file__).resolve().parents[1] / "src")


def test_parallel_q_matches_serial_q():
    """Cell-2 change: parallel-q chunked attention == serial == einsum."""
    ks = [jax.random.fold_in(jax.random.PRNGKey(3), i) for i in range(3)]
    q = jax.random.normal(ks[0], (2, 128, 8, 32))
    k = jax.random.normal(ks[1], (2, 128, 4, 32))
    v = jax.random.normal(ks[2], (2, 128, 4, 32))
    a = _chunked_attention(q, k, v, causal=True, chunk_q=32, chunk_k=32,
                           parallel_q=True)
    b = _chunked_attention(q, k, v, causal=True, chunk_q=32, chunk_k=32)
    c = _einsum_attention(q, k, v, causal=True)
    np.testing.assert_allclose(a, b, rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(a, c, rtol=2e-4, atol=2e-4)


def test_fused_impl_matches_chunked():
    """attn_impl=fused (the kernel region) is numerically the same math."""
    cfg = reduced(get_config("stablelm-1.6b"))
    params = model.init_params(cfg, jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 64), 0, cfg.vocab)
    a, _, _ = model.forward(params, cfg, {"tokens": toks}, impl="fused")
    b, _, _ = model.forward(params, cfg, {"tokens": toks}, impl="chunked")
    np.testing.assert_allclose(a.astype(jnp.float32), b.astype(jnp.float32),
                               rtol=2e-2, atol=2e-2)


def test_int8_kv_cache_decode_accuracy():
    """Cell-3 change: int8 KV cache decode stays close to bf16 decode."""
    cfg = reduced(get_config("stablelm-1.6b"))
    params = model.init_params(cfg, jax.random.PRNGKey(0))
    B, S = 2, 12
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab)

    def run(c):
        cache = model.init_cache(c, B, S, dtype=jnp.float32)
        outs = []
        for t in range(S):
            lg, cache = model.decode_step(params, c, cache,
                                          toks[:, t:t + 1], jnp.int32(t))
            outs.append(lg[:, 0])
        return jnp.stack(outs, 1).astype(jnp.float32)

    ref = run(cfg)
    q8 = run(cfg.replace(kv_cache_dtype="int8"))
    # logits within a small relative band; same argmax for most positions
    agree = jnp.mean((jnp.argmax(ref, -1) == jnp.argmax(q8, -1))
                     .astype(jnp.float32))
    assert float(agree) > 0.9, f"int8 cache argmax agreement {float(agree)}"


MOE_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import dataclasses, jax, jax.numpy as jnp
    from repro.configs.lm import get_config, reduced
    from repro.distributed.sharding import LogicalRules, sharding_context
    from repro.models import moe as MOE

    for arch in ["qwen2-moe-a2.7b", "olmoe-1b-7b"]:
        cfg = reduced(get_config(arch))
        cfg = cfg.replace(moe=dataclasses.replace(cfg.moe, capacity_factor=16.0))
        params = MOE.init_moe(jax.random.PRNGKey(0), cfg)
        x = jax.random.normal(jax.random.PRNGKey(1), (4, 16, cfg.d_model))
        ref, _ = MOE._moe_ffn_math(params, cfg, x)
        mesh = jax.make_mesh((4, 2), ("data", "model"))
        with sharding_context(LogicalRules(mesh)):
            out, _ = jax.jit(lambda p, xx: MOE.moe_ffn(p, cfg, xx))(params, x)
        err = float(jnp.max(jnp.abs(out - ref)))
        assert err < 1e-4, (arch, err)
    print("MOE_SHARDED_OK")
""")


def test_moe_shard_map_equivalence():
    """Cell-1 change: shard_map MoE == dense dispatch (8-device mesh)."""
    out = subprocess.run(
        [sys.executable, "-c", MOE_SCRIPT],
        env={"PYTHONPATH": SRC, "PATH": "/usr/bin:/bin"},
        capture_output=True, text=True, timeout=600)
    assert "MOE_SHARDED_OK" in out.stdout, out.stdout + out.stderr
