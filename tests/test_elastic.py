"""Elastic scaling: degraded-fleet mesh planning + checkpoint-mediated
re-mesh restore."""
import numpy as np
import jax
import jax.numpy as jnp

from repro.checkpoint import save_pytree
from repro.distributed.elastic import plan_mesh, reshard_restore


class TestPlanMesh:
    def test_full_fleet(self):
        shape, axes = plan_mesh(512, model_parallel=16, pod_size=256)
        assert shape == (2, 16, 16) and axes == ("pod", "data", "model")

    def test_one_pod(self):
        shape, axes = plan_mesh(256, model_parallel=16)
        assert shape == (16, 16) and axes == ("data", "model")

    def test_degraded_keeps_model_axis(self):
        # lose half a pod: model parallelism survives, data shrinks
        shape, axes = plan_mesh(128, model_parallel=16)
        assert shape == (8, 16)

    def test_tiny_fleet_shrinks_model(self):
        shape, axes = plan_mesh(8, model_parallel=16)
        assert shape[0] * shape[1] == 8
        assert shape[1] <= 8

    def test_indivisible_device_count(self):
        shape, axes = plan_mesh(24, model_parallel=16)
        assert int(np.prod(shape)) == 24


def test_reshard_restore_roundtrip(tmp_path):
    """Checkpoint written 'elsewhere' restores onto this host's mesh with
    requested shardings (global arrays => mesh-independent)."""
    from jax.sharding import Mesh, PartitionSpec as P
    tree = {"w": jnp.arange(32, dtype=jnp.float32).reshape(4, 8),
            "b": jnp.ones(8)}
    save_pytree(tree, tmp_path / "ckpt.npz")
    mesh = Mesh(np.array(jax.devices()[:1]).reshape(1, 1), ("data", "model"))
    specs = {"w": P(None, None), "b": P(None)}
    restored = reshard_restore({"w": jnp.zeros((4, 8)), "b": jnp.zeros(8)},
                               tmp_path / "ckpt.npz", mesh, specs)
    np.testing.assert_array_equal(restored["w"], tree["w"])
    assert restored["w"].sharding.mesh.shape == {"data": 1, "model": 1}
