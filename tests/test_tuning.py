"""Kernel autotuner: the searched config dimension of the calibrated
registry (PR 10).

Pinned here:
  * ``pick_block`` boundary shapes degrade explicitly (whole-axis
    fallback, min_block floor, ValueError on nonsense);
  * ``enumerate_configs`` is deterministic, predicate-filtered, and
    boundable (the CI smoke's 2-configs-per-kernel cap);
  * every candidate config is numerics-preserving at real shapes
    (fuzzed sample per tunable kernel, bitwise except ``marg_schur``'s
    documented accumulation-order tolerance);
  * tune() -> save -> load -> decide_path reproduces the winning config
    EXACTLY, and a profile tuned on foreign hardware is refused like
    foreign latency coefficients;
  * dispatch applies the installed winner, explicit kwargs outrank it;
  * config changes recompile at plan-resolution time (``KernelConfigs``
    is leafless static aux data), never mid-run.
"""
import json

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import scheduler as sched
from repro.core.step import EMPTY_CONFIGS, KernelConfigs, PlanFlags
from repro.kernels import registry, tuning
from repro.kernels.common import pick_block


@pytest.fixture(autouse=True)
def _clean_models():
    registry.install_models(None)
    yield
    registry.install_models(None)


# ---------------------------------------------------------------------------
# pick_block boundary shapes
# ---------------------------------------------------------------------------
def test_pick_block_basic_divisors():
    assert pick_block(256, 128) == 128
    assert pick_block(384, 256) == 192      # largest divisor <= target
    assert pick_block(100, 128) == 100      # dim <= target: whole axis


def test_pick_block_prime_degenerates_to_one():
    assert pick_block(13, 8) == 1


def test_pick_block_min_block_fallback_is_whole_axis():
    # no divisor of 13 in [4, 8] -> the validated fallback is ONE
    # whole-axis block, never a sub-minimum tile
    assert pick_block(13, 8, min_block=4) == 13
    # a qualifying divisor is still preferred over the fallback
    assert pick_block(12, 8, min_block=4) == 6


def test_pick_block_rejects_nonpositive():
    with pytest.raises(ValueError):
        pick_block(0, 8)
    with pytest.raises(ValueError):
        pick_block(8, 0)
    with pytest.raises(ValueError):
        pick_block(8, 4, min_block=0)


# ---------------------------------------------------------------------------
# config enumeration: deterministic, predicate-filtered, boundable
# ---------------------------------------------------------------------------
def test_enumerate_configs_deterministic_product():
    spec = registry.REGISTRY["matmul"]
    args = registry._matmul_inputs(256)
    configs = tuning.enumerate_configs(spec, *args)
    assert configs == tuning.enumerate_configs(spec, *args)
    # full product at an every-candidate-valid size
    assert len(configs) == 3 * 2 * 2
    assert all(set(c) == {"bm", "bk", "bn"} for c in configs)


def test_enumerate_configs_filters_invalid_tilings():
    # at n=384, pick_block(384, 256) = 192 which breaks the 128-lane
    # alignment -> every bk=256 / bn=256 candidate must be filtered
    spec = registry.REGISTRY["matmul"]
    args = registry._matmul_inputs(384)
    configs = tuning.enumerate_configs(spec, *args)
    assert configs
    assert all(c["bk"] != 256 and c["bn"] != 256 for c in configs)


def test_enumerate_configs_max_configs_is_a_prefix():
    spec = registry.REGISTRY["matmul"]
    args = registry._matmul_inputs(256)
    full = tuning.enumerate_configs(spec, *args)
    assert tuning.enumerate_configs(spec, *args, max_configs=2) == full[:2]


def test_tunable_kernels_cover_the_spine():
    assert set(registry.MEGAKERNELS) <= set(registry.TUNABLE_KERNELS)
    assert "matmul" in registry.TUNABLE_KERNELS
    # the LM-era flash kernel is quarantined from the registry surface
    assert "flash" not in registry.REGISTRY
    assert "flash" not in registry.TUNABLE_KERNELS


# ---------------------------------------------------------------------------
# every candidate is numerics-preserving at real shapes (fuzzed sample)
# ---------------------------------------------------------------------------
def _leaves(x):
    return [np.asarray(v) for v in jax.tree_util.tree_leaves(x)]


@pytest.mark.parametrize("name", registry.TUNABLE_KERNELS)
def test_config_space_parity_fuzzed(name):
    spec = registry.REGISTRY[name]
    args = spec.calibrate_inputs(spec.calibrate_sizes[0])
    configs = tuning.enumerate_configs(spec, *args)
    assert configs, f"{name} declared a tuning space with no valid config"
    rs = np.random.RandomState(hash(name) % (2**31))
    sample = [configs[i] for i in
              rs.choice(len(configs), size=min(4, len(configs)),
                        replace=False)]
    base = _leaves(spec.pallas(*args))
    for config in sample:
        out = _leaves(spec.pallas(*args, **config))
        for b, o in zip(base, out):
            if name == "marg_schur":
                # the landmark tile size reorders a float accumulation
                np.testing.assert_allclose(o, b, rtol=1e-5, atol=1e-5)
            else:
                np.testing.assert_array_equal(o, b)


def test_cov_update_block_k_is_bitwise():
    """The sweep stays strictly sequential at any block_k — bitwise, not
    just close (the plan may swap configs between runs; trajectories
    must not move)."""
    spec = registry.REGISTRY["cov_update"]
    args = spec.calibrate_inputs(spec.calibrate_sizes[0])
    base = _leaves(spec.pallas(*args))
    for bk in spec.tuning_space["block_k"]:
        out = _leaves(spec.pallas(*args, block_k=bk))
        for b, o in zip(base, out):
            np.testing.assert_array_equal(o, b)


# ---------------------------------------------------------------------------
# TunedProfile bucket semantics
# ---------------------------------------------------------------------------
def test_profile_bucket_lookup():
    prof = tuning.TunedProfile()
    prof.record("k", 100, {"a": 1})
    prof.record("k", 1000, {"a": 2})
    assert prof.lookup("k", 50) == {"a": 1}      # smallest covering bucket
    assert prof.lookup("k", 100) == {"a": 1}
    assert prof.lookup("k", 500) == {"a": 2}
    assert prof.lookup("k", 5000) == {"a": 2}    # past the sweep: largest
    assert prof.lookup("other", 100) is None


def test_profile_records_default_winners_explicitly():
    prof = tuning.TunedProfile()
    prof.record("k", 64, {})
    assert "k" in prof.kernels()                 # the decision is recorded
    assert prof.lookup("k", 64) is None          # ...but yields no kwargs
    assert tuning.TunedProfile.from_json(prof.to_json()) == prof


# ---------------------------------------------------------------------------
# tune() round trip: search -> persist -> load -> decide_path
# ---------------------------------------------------------------------------
def _temp_spec(name):
    """A tiny registered spec with a 3-candidate space and a recording
    pallas path (so dispatch's applied kwargs are observable)."""
    calls = []

    def pallas(x, blk=8, **kw):
        calls.append({"blk": blk})
        return x

    spec = registry.KernelSpec(
        name=name, xla=lambda x, **kw: x, pallas=pallas,
        size_feature=lambda x, **kw: float(x.shape[0]),
        transfer_bytes=lambda x, **kw: 4 * x.size,
        supports=lambda x, **kw: True,
        calibrate_inputs=lambda n: (jnp.ones((n, 128), jnp.float32),),
        calibrate_sizes=(64,),
        tuning_space={"blk": (8, 16, 32)})
    registry.REGISTRY[name] = spec
    return spec, calls


def test_tune_roundtrip_reproduces_winner(tmp_path, monkeypatch):
    name = "_tuning_test_kernel"
    _, calls = _temp_spec(name)
    # deterministic timer: default 1.0, then blk=8 -> 0.5, blk=16 -> 0.2,
    # blk=32 -> 0.9 (enumeration order) => the winner is blk=16
    times = iter([1.0, 0.5, 0.2, 0.9])
    monkeypatch.setattr(tuning.sched, "profile_fn",
                        lambda fn, reps=3: (fn(), next(times))[1])
    path = str(tmp_path / "models.json")
    try:
        models = tuning.tune(kernels=(name,), reps=1, install=False,
                             path=path)
        assert models.tuned.buckets(name) == [(64.0, {"blk": 16})]

        loaded = registry.load_models(path)
        assert loaded.tuned == models.tuned
        registry.install_models(loaded)
        monkeypatch.setenv("REPRO_KERNELS", "pallas")
        x = jnp.ones((64, 128), jnp.float32)
        d = registry.decide_path(name, x)
        assert d == "pallas" and d.config == {"blk": 16}

        # dispatch applies the winner; explicit kwargs outrank it
        calls.clear()
        registry.dispatch(name, x)
        assert calls == [{"blk": 16}]
        registry.dispatch(name, x, blk=99)
        assert calls[-1] == {"blk": 99}
        # uninstalled profile -> the built-in default, bitwise fallback
        registry.install_models(None)
        registry.dispatch(name, x)
        assert calls[-1] == {"blk": 8}
    finally:
        del registry.REGISTRY[name]


def test_tuned_profile_fingerprint_refusal(tmp_path):
    lm = sched.LatencyModels()
    sizes = np.linspace(64, 1024, 8)
    lm.fit_kernel("projection", sizes, 1e-6 * sizes, 1e-7 * sizes)
    prof = tuning.TunedProfile()
    prof.record("matmul", 2**21, {"bm": 64})
    lm.tuned = prof
    path = str(tmp_path / "models.json")
    registry.save_models(lm, path)
    with open(path) as f:
        blob = json.load(f)
    assert blob["tuned"] == prof.to_json()   # rides the schema-v2 blob
    for key, val in (("device_kind", "EDX-CAR FPGA"),
                     ("device_count", "512")):
        bad = json.loads(json.dumps(blob))
        bad["fingerprint"][key] = val
        with open(path, "w") as f:
            json.dump(bad, f)
        with pytest.raises(registry.CalibrationMismatch):
            registry.load_models(path)
        # the explicit escape hatch still carries the profile across
        loaded = registry.load_models(path, allow_mismatch=True)
        assert loaded.tuned == prof


def test_decide_path_string_compat():
    """Decision keeps comparing like the old plain-string returns."""
    d = registry.Decision("xla")
    assert d == "xla" and d != "pallas"
    p = registry.Decision("pallas", {"bm": 64})
    assert p == "pallas" and p != "xla"
    assert p != registry.Decision("pallas", {"bm": 128})
    assert p == registry.Decision("pallas", {"bm": 64})
    assert len({d, registry.Decision("xla")}) == 1


# ---------------------------------------------------------------------------
# config changes recompile at load time, never mid-run
# ---------------------------------------------------------------------------
def test_kernel_configs_static_pytree_semantics():
    c = KernelConfigs({"marg_schur": {"mb": 8}, "empty": {}})
    assert c and c.get("marg_schur") == {"mb": 8}
    assert c.get("empty") == {} and c.get("missing") == {}
    assert not jax.tree_util.tree_leaves(c)      # leafless: static aux
    assert c == KernelConfigs({"marg_schur": {"mb": 8}})
    assert hash(c) == hash(KernelConfigs({"marg_schur": {"mb": 8}}))
    assert not EMPTY_CONFIGS and c != EMPTY_CONFIGS


def test_config_change_retraces_next_dispatch():
    traces = []

    @jax.jit
    def f(configs, x):
        traces.append(1)
        return x + len(configs.get("k"))

    x = jnp.ones((2,))
    f(KernelConfigs({"k": {"a": 1}}), x)
    f(KernelConfigs({"k": {"a": 1}}), x)
    assert len(traces) == 1                      # same config: one trace
    f(KernelConfigs({"k": {"a": 1, "b": 2}}), x)
    assert len(traces) == 2                      # changed config: retrace


def test_offload_plan_threads_configs_to_flags():
    plan = sched.OffloadPlan(configs={"marg_schur": {"mb": 8},
                                      "nothing": {}})
    assert plan.configs == {"marg_schur": {"mb": 8}}
    # replace() preserves configs unless overridden
    plan2 = plan.replace(msckf_update=False)
    assert plan2.configs == plan.configs
    plan3 = plan.replace(configs={})
    assert plan3.configs == {}
    # equality sees configs (a swapped profile is a different plan)
    assert plan != plan3
    flags = PlanFlags(gates=(), active=None,
                      configs=KernelConfigs(plan.configs))
    assert flags.configs.get("marg_schur") == {"mb": 8}
