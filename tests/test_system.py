"""End-to-end behaviour of the paper's system: mode selection (Fig. 2),
full localization runs per mode, variation tracking, map handoff."""
import numpy as np
import pytest

from repro.core.environment import Environment, Mode, select_mode
from repro.core.localizer import Localizer


def test_mode_taxonomy_matches_fig2():
    assert select_mode(Environment(False, False)) == Mode.SLAM
    assert select_mode(Environment(False, True)) == Mode.REGISTRATION
    assert select_mode(Environment(True, False)) == Mode.VIO
    assert select_mode(Environment(True, True)) == Mode.VIO


def run_sequence(seq, cfg, env, n_frames=None, with_map=None, window=8):
    loc = Localizer(cfg, seq.cam, window=window)
    if with_map is not None:
        loc.map = with_map
    v0 = (seq.poses[1][:3, 3] - seq.poses[0][:3, 3]) / seq.dt
    st = loc.init_state(p0=seq.poses[0][:3, 3], v0=v0)
    ipf = seq.imu_per_frame
    n = n_frames or len(seq.images_left)
    for i in range(n):
        a = seq.imu_accel[max(i - 1, 0) * ipf:max(i, 1) * ipf]
        g = seq.imu_gyro[max(i - 1, 0) * ipf:max(i, 1) * ipf]
        gps = seq.gps[i] if env.gps_available else None
        st = loc.step(st, seq.images_left[i], seq.images_right[i],
                      a, g, gps, env, seq.dt / ipf)
    return loc


def test_vio_gps_mode(synthetic_sequence, small_cfg):
    """Outdoor (paper Fig. 3c/d): VIO+GPS should be decimeter-accurate."""
    env = Environment(gps_available=True, map_available=False)
    loc = run_sequence(synthetic_sequence, small_cfg, env, n_frames=10)
    rmse = loc.rmse(synthetic_sequence.poses[:, :3, 3])
    assert rmse < 0.25, f"VIO+GPS rmse {rmse}"
    assert len(loc.variation[Mode.VIO].samples) == 10


def test_slam_builds_map_and_localizes(synthetic_sequence, small_cfg):
    """Indoor unknown (Fig. 3a): SLAM localizes and produces a map."""
    env = Environment(gps_available=False, map_available=False)
    loc = run_sequence(synthetic_sequence, small_cfg, env, n_frames=10)
    rmse = loc.rmse(synthetic_sequence.poses[:, :3, 3])
    assert rmse < 1.0, f"SLAM rmse {rmse}"
    assert loc.map is not None and loc.map.valid.sum() >= 50
    assert loc.map.keyframe_hists.shape[0] >= 5


def test_registration_with_slam_map(synthetic_sequence, small_cfg):
    """Indoor known (Fig. 3b): registration against the persisted map —
    the paper's SLAM -> map -> registration handoff."""
    env_slam = Environment(False, False)
    loc_slam = run_sequence(synthetic_sequence, small_cfg, env_slam,
                            n_frames=10)
    env_reg = Environment(False, True)
    loc_reg = run_sequence(synthetic_sequence, small_cfg, env_reg,
                           n_frames=10, with_map=loc_slam.map)
    rmse = loc_reg.rmse(synthetic_sequence.poses[:, :3, 3])
    assert rmse < 1.0, f"registration rmse {rmse}"


def test_variation_tracked_per_mode(synthetic_sequence, small_cfg):
    env = Environment(True, False)
    loc = run_sequence(synthetic_sequence, small_cfg, env, n_frames=6)
    stats = loc.variation[Mode.VIO].stats()
    assert stats["mean"] > 0 and stats["worst_over_best"] >= 1.0
