"""Checkpointing: roundtrip, retention, restart semantics, atomicity."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.checkpoint import (Checkpointer, latest_step, restore_pytree,
                              save_pytree)


def make_tree(x=1.0):
    return {"params": {"w": jnp.full((4, 8), x), "b": jnp.zeros(8)},
            "opt": {"m": (jnp.ones(3), jnp.zeros(2))},
            "step": jnp.int32(7)}


def test_roundtrip(tmp_path):
    t = make_tree(3.5)
    save_pytree(t, tmp_path / "x.npz")
    r = restore_pytree(make_tree(0.0), tmp_path / "x.npz")
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(r)):
        np.testing.assert_array_equal(a, b)


def test_shape_mismatch_raises(tmp_path):
    save_pytree(make_tree(), tmp_path / "x.npz")
    bad = make_tree()
    bad["params"]["w"] = jnp.zeros((5, 8))
    with pytest.raises(AssertionError):
        restore_pytree(bad, tmp_path / "x.npz")


def test_async_checkpointer_retention(tmp_path):
    c = Checkpointer(tmp_path, keep=2)
    for s in [10, 20, 30, 40]:
        c.save(s, make_tree(float(s)))
    c.wait()
    assert latest_step(tmp_path) == 40
    steps = sorted(int(f.stem.split("_")[1]) for f in tmp_path.glob("step_*.npz"))
    assert steps == [30, 40]
    step, restored = c.restore_latest(make_tree(0.0))
    assert step == 40
    assert float(restored["params"]["w"][0, 0]) == 40.0
    c.close()


def test_no_tmp_leftovers(tmp_path):
    c = Checkpointer(tmp_path)
    c.save(1, make_tree())
    c.wait()
    assert not list(tmp_path.glob("*.tmp.npz")), "atomic rename must clean up"
    c.close()


def test_restart_determinism(tmp_path):
    """Train 6 steps straight vs 3 + restore + 3: identical final params."""
    from repro.configs.lm import get_config, reduced
    from repro.data.tokens import TokenStream
    from repro.launch import steps as steps_lib

    cfg = reduced(get_config("stablelm-1.6b"), n_layers=2)
    step_fn = jax.jit(steps_lib.make_train_step(cfg))
    stream = TokenStream(cfg.vocab, 4, 32, seed=0)

    def run(state, lo, hi):
        for s in range(lo, hi):
            state, _ = step_fn(state, {"tokens": jnp.asarray(
                stream.batch_at(s)["tokens"])})
        return state

    rng = jax.random.PRNGKey(0)
    s_straight = run(steps_lib.init_train_state(cfg, rng), 0, 6)

    s_a = run(steps_lib.init_train_state(cfg, rng), 0, 3)
    save_pytree(s_a, tmp_path / "mid.npz")
    s_b = restore_pytree(steps_lib.init_train_state(cfg, rng),
                         tmp_path / "mid.npz")
    s_restart = run(s_b, 3, 6)

    for a, b in zip(jax.tree.leaves(s_straight["params"]),
                    jax.tree.leaves(s_restart["params"])):
        np.testing.assert_allclose(a, b, rtol=1e-6, atol=1e-6)
