"""Sharded fleet execution: robots mesh + shard_map over the B axis.

In-process tests run on the real (single-device) CPU: a 1-device mesh
must be bitwise-equal to the unsharded FleetLocalizer path, and the
per-robot flush policy must keep mixed fleets exact while deferring
SLAM replay. Multi-device behavior (B=5 on 4 forced host devices:
padding, per-shard staging/donation, sharded==unsharded equivalence)
runs in a subprocess with ``--xla_force_host_platform_device_count``,
which must be set before JAX initializes."""
import dataclasses
import subprocess
import sys
from pathlib import Path

import numpy as np
import pytest

SRC = str(Path(__file__).resolve().parents[1] / "src")


# ---------------------------------------------------------------------------
# mesh helpers
# ---------------------------------------------------------------------------

def test_fleet_mesh_helpers():
    import jax
    from repro.distributed.fleet_mesh import (ROBOTS_AXIS, fleet_mesh,
                                              mesh_shards, padded_batch)
    mesh = fleet_mesh()
    assert mesh.axis_names == (ROBOTS_AXIS,)
    assert mesh_shards(mesh) == len(jax.devices())
    assert mesh_shards(None) == 1
    # padding: smallest multiple of the shard count >= batch
    one = fleet_mesh(jax.devices()[:1])
    assert padded_batch(5, one) == 5
    assert padded_batch(5, None) == 5
    with pytest.raises(ValueError):
        fleet_mesh([])


def test_package_exports_localization_only():
    """The distributed package's public surface is the robots mesh; the
    seed's LLM logical-axis table stays quarantined behind an explicit
    submodule import."""
    import repro.distributed as dist
    assert "fleet_mesh" in dist.__all__
    assert "LogicalRules" not in dist.__all__
    assert not hasattr(dist, "default_rules")
    # quarantined module still importable directly (models/ needs it)
    from repro.distributed import sharding
    assert hasattr(sharding, "LogicalRules")


# ---------------------------------------------------------------------------
# shared small workload (48x64 keeps per-test compile time down)
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def shard_seq():
    from repro.data import frames
    return frames.generate(n_frames=8, H=48, W=64, n_landmarks=200,
                           accel_sigma=0.5, gyro_sigma=0.02, seed=0)


@pytest.fixture(scope="module")
def shard_cfg():
    from repro.configs.eudoxus import EDX_DRONE
    fe = dataclasses.replace(EDX_DRONE.frontend, height=48, width=64,
                             max_features=48)
    be = dataclasses.replace(EDX_DRONE.backend, ba_window=4,
                             ba_landmarks=16, lm_iters=2)
    return dataclasses.replace(EDX_DRONE, frontend=fe, backend=be)


def _fleet_sequence(seq, B, T, modes):
    from repro.core.environment import MODE_VIO
    from repro.data.frames import tile_fleet_sequence
    il, ir, ac, gy, gps = tile_fleet_sequence(seq, B, T)
    gps[:, np.asarray(modes) != MODE_VIO] = np.nan
    return il, ir, ac, gy, gps


def _drive(cfg, seq, B, T, modes, mesh=None, overlap=True, chunk=3):
    from repro.core.fleet import FleetLocalizer
    il, ir, ac, gy, gps = _fleet_sequence(seq, B, T, modes)
    fleet = FleetLocalizer(cfg, seq.cam, batch=B, window=4, mesh=mesh)
    states = fleet.init_state(p0=np.tile(seq.poses[0][:3, 3], (B, 1)))
    states = fleet.run(states, il, ir, ac, gy, gps, modes,
                       seq.dt / seq.imu_per_frame, chunk=chunk,
                       overlap=overlap)
    return fleet, states


# ---------------------------------------------------------------------------
# 1-device mesh: provably behavior-preserving
# ---------------------------------------------------------------------------

def test_one_device_mesh_bitwise_equal_mixed_modes(shard_cfg, shard_seq):
    """The sharded execution layer on a 1-device robots mesh is
    BITWISE-equal to the pre-refactor single-device path — mixed
    VIO/SLAM/Registration fleet, async pipeline, chunked run."""
    import jax
    from repro.core.environment import (MODE_REGISTRATION, MODE_SLAM,
                                        MODE_VIO)
    from repro.distributed.fleet_mesh import fleet_mesh
    modes = np.array([MODE_VIO, MODE_SLAM, MODE_REGISTRATION], np.int32)
    B, T = 3, 7                      # T=7, K=3: exercises a partial chunk
    f0, s0 = _drive(shard_cfg, shard_seq, B, T, modes, mesh=None)
    mesh1 = fleet_mesh(jax.devices()[:1])
    f1, s1 = _drive(shard_cfg, shard_seq, B, T, modes, mesh=mesh1)
    for a, b in zip(jax.tree_util.tree_leaves(s0),
                    jax.tree_util.tree_leaves(s1)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # host stages saw identical frame streams
    assert len(f0._robots[1]._slam_keyframes) == T
    assert len(f1._robots[1]._slam_keyframes) == T
    # the mesh path really staged per-shard: staged inputs carry the
    # robots-mesh sharding and every consumed slot was donated back
    assert f1.last_stager is not None
    slots = [s for s in f1.last_stager._slots if s is not None]
    assert slots and all(s.consumed for s in slots)
    for s in slots:
        leaves = jax.tree_util.tree_leaves(s.inputs)
        assert any(leaf.is_deleted() for leaf in leaves), \
            "consumed staged buffers must be donated to their dispatch"


def test_per_robot_flush_defers_slam_replay(shard_cfg, shard_seq):
    """Per-robot chunk-flush policy: with a Registration robot in the
    fleet, only ITS chunk-end slices sync before the next dispatch —
    SLAM replay still defers one chunk (the old fleet-wide policy
    drained everything immediately), and the async pipeline stays exact
    vs the synchronous loop."""
    import jax
    from repro.core.environment import (MODE_REGISTRATION, MODE_SLAM,
                                        MODE_VIO)
    modes = np.array([MODE_VIO, MODE_SLAM, MODE_REGISTRATION], np.int32)
    B, T = 3, 6
    fa, sa = _drive(shard_cfg, shard_seq, B, T, modes, overlap=True)
    fs, ss = _drive(shard_cfg, shard_seq, B, T, modes, overlap=False)
    for a, b in zip(jax.tree_util.tree_leaves(sa),
                    jax.tree_util.tree_leaves(ss)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert len(fa._robots[1]._slam_keyframes) == T
    assert len(fs._robots[1]._slam_keyframes) == T
    # the pipeline kept SLAM replay one chunk behind despite the
    # Registration robot's per-chunk feedback
    assert fa.deferred_drains > 0
    assert fs.deferred_drains == 0       # sync loop never defers


def test_positions_strips_padding(shard_cfg, shard_seq):
    """`positions` returns the REAL batch regardless of internal mesh
    padding (trivially so on a 1-device mesh)."""
    import jax
    from repro.core.environment import MODE_VIO
    from repro.core.fleet import FleetLocalizer
    from repro.distributed.fleet_mesh import fleet_mesh
    fleet = FleetLocalizer(shard_cfg, shard_seq.cam, batch=2, window=4,
                           mesh=fleet_mesh(jax.devices()[:1]))
    states = fleet.init_state()
    assert fleet.positions(states).shape == (2, 3)
    assert fleet.padded % fleet.n_shards == 0


# ---------------------------------------------------------------------------
# mesh-aware calibration fingerprint
# ---------------------------------------------------------------------------

def test_fingerprint_records_device_count(tmp_path):
    """Latency profiles are only valid at the device count they were
    taken at: a profile stamped with a different count refuses to load
    and ``load_or_refit`` re-profiles."""
    import json
    import jax
    from repro.core import scheduler as sched
    from repro.kernels import registry
    fp = registry.device_fingerprint()
    assert fp["device_count"] == str(len(jax.devices()))

    path = tmp_path / "models.json"
    models = sched.LatencyModels()
    models.fit_kernel("projection", np.array([1., 2., 3.]),
                      np.array([1e-3, 2e-3, 3e-3]),
                      np.array([1e-4, 2e-4, 3e-4]))
    registry.save_models(models, str(path))
    blob = json.loads(path.read_text())
    blob["fingerprint"]["device_count"] = "512"      # a foreign mesh
    path.write_text(json.dumps(blob))
    with pytest.raises(registry.CalibrationMismatch):
        registry.load_models(str(path))
    _, cached = registry.load_or_refit(str(path), install=False,
                                       kernels=("projection",), reps=1)
    assert not cached                                # refit, not reuse
    fresh = json.loads(path.read_text())
    assert fresh["fingerprint"] == registry.device_fingerprint()
    registry.install_models(None)


def test_fleet_plan_is_shard_invariant():
    """`plan_fleet_chunk` resolves ONE plan valid across shards: its
    model inputs are per-robot static shapes and the amortization uses
    the per-shard local batch, so any (batch, shards) pair with the same
    local batch resolves identically — and the degenerate case equals
    ``plan_chunk``."""
    from repro.core import scheduler as sched
    lm = sched.LatencyModels()
    sizes = np.array([16., 64., 256.])
    # host wins at small sizes once overhead is added
    lm.fit_kernel("kalman_gain", sizes, sizes * 1e-6, sizes * 0.9e-6)
    base = lm.plan_chunk(window=8, max_updates=24, chunk=4)
    assert lm.plan_fleet_chunk(window=8, max_updates=24, chunk=4) == base
    p8_4 = lm.plan_fleet_chunk(window=8, max_updates=24, chunk=4,
                               batch=8, shards=4)
    p4_2 = lm.plan_fleet_chunk(window=8, max_updates=24, chunk=4,
                               batch=4, shards=2)
    assert p8_4 == p4_2                  # same local batch -> same plan


# ---------------------------------------------------------------------------
# multi-device: B=5 on 4 forced host devices (subprocess)
# ---------------------------------------------------------------------------

MULTIDEV_SCRIPT = r"""
import dataclasses
import numpy as np
import jax

assert len(jax.devices()) == 4, jax.devices()

from repro.configs.eudoxus import EDX_DRONE
from repro.core.environment import MODE_SLAM, MODE_VIO
from repro.core.fleet import FleetLocalizer
from repro.data import frames
from repro.distributed.fleet_mesh import fleet_mesh

fe = dataclasses.replace(EDX_DRONE.frontend, height=48, width=64,
                         max_features=48)
be = dataclasses.replace(EDX_DRONE.backend, ba_window=4, ba_landmarks=16,
                         lm_iters=2)
cfg = dataclasses.replace(EDX_DRONE, frontend=fe, backend=be)
seq = frames.generate(n_frames=7, H=48, W=64, n_landmarks=200,
                      accel_sigma=0.5, gyro_sigma=0.02, seed=0)
B, T = 5, 7                       # B=5 on 4 devices: padding path
il, ir, ac, gy, gps = frames.tile_fleet_sequence(seq, B, T)
modes = np.array([MODE_VIO, MODE_SLAM, MODE_VIO, MODE_VIO, MODE_VIO],
                 np.int32)
gps[:, modes != MODE_VIO] = np.nan
p0 = np.tile(seq.poses[0][:3, 3], (B, 1))
dt = seq.dt / seq.imu_per_frame


def drive(mesh, overlap=True):
    f = FleetLocalizer(cfg, seq.cam, batch=B, window=4, mesh=mesh)
    s = f.init_state(p0=p0)
    s = f.run(s, il, ir, ac, gy, gps, modes, dt, chunk=3, overlap=overlap)
    return f, s


f0, s0 = drive(None)
f4, s4 = drive(fleet_mesh())
assert f4.padded == 8 and f4._pad == 3, (f4.padded, f4._pad)
# sharded == unsharded on the REAL batch (mixed modes, partial chunk)
for name in ("p", "q", "v", "P"):
    a = np.asarray(getattr(s0.filt, name))[:B]
    b = np.asarray(getattr(s4.filt, name))[:B]
    np.testing.assert_array_equal(a, b, err_msg=name)
np.testing.assert_array_equal(np.asarray(s0.tracks_valid)[:B],
                              np.asarray(s4.tracks_valid)[:B])
assert len(f0._robots[1]._slam_keyframes) == T
assert len(f4._robots[1]._slam_keyframes) == T
# state genuinely split across all 4 shards
assert len(s4.filt.p.sharding.device_set) == 4, s4.filt.p.sharding
# pad robots never advanced (inactive in every chunk)
assert np.asarray(s4.frame_idx)[B:].max() == 0
# stager-per-shard donation discipline: staged inputs carried the mesh
# sharding and consumed slots were donated back to their dispatch
slots = [s for s in f4.last_stager._slots if s is not None]
assert slots and all(s.consumed for s in slots)
for s in slots:
    leaves = jax.tree_util.tree_leaves(s.inputs)
    live = [leaf for leaf in leaves if not leaf.is_deleted()]
    assert len(live) < len(leaves), "no staged buffer was donated back"
    for leaf in live:         # whatever survives still spans the mesh
        assert len(leaf.sharding.device_set) == 4

# per-frame sharded step path: same padding, finite results
fstep = FleetLocalizer(cfg, seq.cam, batch=B, window=4, mesh=fleet_mesh())
ss = fstep.init_state(p0=p0)
ss, _ = fstep.step(ss, il[0], ir[0], ac[0], gy[0], gps[0], modes, dt)
assert np.isfinite(fstep.positions(ss)).all()
assert fstep.positions(ss).shape == (B, 3)
print("FLEET_SHARD_MULTIDEV_OK")
"""


def test_sharded_fleet_multidevice_subprocess():
    out = subprocess.run(
        [sys.executable, "-c", MULTIDEV_SCRIPT],
        # force the CPU platform + 4 host devices; XLA reads the flag at
        # init, hence the subprocess
        env={"PYTHONPATH": SRC, "PATH": "/usr/bin:/bin",
             "JAX_PLATFORMS": "cpu",
             "XLA_FLAGS": "--xla_force_host_platform_device_count=4"},
        capture_output=True, text=True, timeout=900)
    assert "FLEET_SHARD_MULTIDEV_OK" in out.stdout, \
        out.stdout + out.stderr
